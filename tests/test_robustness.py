"""Fault-domain topology, imperfect detection, and split-brain fencing
(ISSUE 7):

  * `FaultDomainTree` units — rank/host/switch mapping, proximity classes,
    domain-token expansion, the flat degenerate tree;
  * suspicion-based detection — a SIGKILL is confirmed at `timeout_s`, a
    hang/partition/heartbeat-loss only after the longer grace window, a
    healthy detector without heartbeat traffic never mass-suspects, and a
    false suspicion is cleared by reintegration;
  * sigkill vs hang produce *measurably different* `detect` span durations
    (the span reports real heartbeat age, not a configured constant);
  * placement replica anti-affinity across hosts and proximity-aware
    Tier-2 repair sources;
  * the fence: a falsely-suspected healthy rank is fenced (epoch bump),
    late writes die on the epoch check, the rank rejoins, and clients see
    ZERO error events with clean stream ordering;
  * partitions: the majority commits a lease-fenced shrink, heal lands as
    ONE batched reintegration, and the epoch never regresses across any
    partition/heal interleaving (deterministic enumeration always; a
    hypothesis property when the dev extra is installed);
  * graceful degradation on coverage loss — structured REJECTED/FAILED
    events, the engine keeps stepping;
  * the admin surface exposes suspicion state, fence events and the
    fault-domain tree as round-trippable JSON.
"""
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import make_initial_membership
from repro.core.failure import FailureDetector, SimClock
from repro.core.placement import eplb_place
from repro.core.reintegration import WarmupCostModel
from repro.core.repair import plan_repair
from repro.core.scenarios import Scenario, parse_schedule
from repro.core.topology import FaultDomainTree, flat_topology
from repro.models import init_params
from repro.runtime.elastic import ElasticEPRuntime
from repro.runtime.scenario_runner import run_scenario
from repro.serving.api import ServingFrontend
from repro.serving.engine import ServingEngine


# ---------------------------------------------------------------------------
# FaultDomainTree
# ---------------------------------------------------------------------------

def test_topology_mapping_and_proximity():
    topo = FaultDomainTree(world=8, ranks_per_host=2, hosts_per_switch=2)
    assert topo.num_hosts == 4 and topo.num_switches == 2
    assert [topo.host_of(r) for r in range(8)] == [0, 0, 1, 1, 2, 2, 3, 3]
    assert [topo.switch_of(r) for r in range(8)] == [0, 0, 0, 0, 1, 1, 1, 1]
    assert topo.ranks_of_host(1) == (2, 3)
    assert topo.ranks_of_switch(1) == (4, 5, 6, 7)
    assert topo.proximity(0, 1) == 0          # same host: ICI
    assert topo.proximity(0, 2) == 1          # same switch: host NIC
    assert topo.proximity(0, 4) == 2          # cross-switch: spine
    assert list(topo.rank_host_array()) == [0, 0, 1, 1, 2, 2, 3, 3]
    assert topo.rank_host_array().dtype == np.int32


def test_topology_ragged_last_domain():
    topo = FaultDomainTree(world=7, ranks_per_host=3, hosts_per_switch=2)
    assert topo.num_hosts == 3 and topo.num_switches == 2
    assert topo.ranks_of_host(2) == (6,)      # packed, last host smaller
    assert topo.ranks_of_switch(1) == (6,)


def test_topology_expand_targets_dedup_sorted():
    topo = FaultDomainTree(world=8, ranks_per_host=2, hosts_per_switch=2)
    assert topo.expand("host:1") == (2, 3)
    assert topo.expand("switch:0") == (0, 1, 2, 3)
    # explicit rank overlapping a domain fails once
    assert topo.expand_targets((3, 6), ("host:1",)) == [2, 3, 6]


def test_flat_topology_degenerates():
    topo = flat_topology(5)
    assert topo.num_hosts == 5 and topo.num_switches == 1
    assert all(topo.host_of(r) == r for r in range(5))
    assert all(topo.proximity(a, b) == (0 if a == b else 1)
               for a in range(5) for b in range(5))


def test_topology_json_roundtrip():
    topo = FaultDomainTree(world=8, ranks_per_host=2, hosts_per_switch=2)
    j = json.loads(json.dumps(topo.to_json()))
    assert j["hosts"]["1"] == [2, 3]
    assert j["switches"]["1"] == [2, 3]


# ---------------------------------------------------------------------------
# Scenario DSL: new ops
# ---------------------------------------------------------------------------

def test_parse_schedule_domains_kinds_roundtrip():
    src = ("@1 fail host:1\n@2 fail 2 kind=hang\n@3 suspect 4 x2.5\n"
           "@4 partition switch:1\n@10 heal")
    acts = parse_schedule(src)
    assert acts[0].domains == ("host:1",) and acts[0].op == "fail"
    assert acts[1].kind == "hang"
    assert acts[2].op == "suspect" and acts[2].factor == 2.5
    assert acts[3].op == "partition" and acts[3].domains == ("switch:1",)
    assert acts[4].op == "heal" and acts[4].ranks == ()
    from repro.core.scenarios import format_schedule
    assert parse_schedule(format_schedule(acts)) == acts


@pytest.mark.parametrize("bad", [
    "@1 fail rack:0",           # unknown domain kind
    "@1 fail host:x",           # bad domain index
    "@1 fail host:-1",          # negative domain index
    "@1 fail 2 kind=meteor",    # unknown fail kind
    "@1 suspect 3",             # suspect without duration
    "@1 partition",             # partition without targets
    "@1 drain host:0",          # domains only on fail/partition
])
def test_parse_schedule_rejects_new_ops(bad):
    with pytest.raises(ValueError):
        parse_schedule(bad)


def test_scenario_validate_rejects_out_of_range_domain():
    scn = Scenario(name="x", description="", schedule="@1 fail host:9",
                   world=8)
    with pytest.raises(ValueError):
        scn.validate()


# ---------------------------------------------------------------------------
# Suspicion-based detection
# ---------------------------------------------------------------------------

def _detector(world=8, **kw):
    clock = SimClock()
    det = FailureDetector(world, clock, **kw)
    det.heartbeat()                    # monitoring plane live at t=0
    return clock, det


def test_sigkill_confirmed_at_timeout_only():
    clock, det = _detector()
    det.mark_unreachable(5)
    clock.advance(0.9)
    det.heartbeat()
    assert det.poll() == []
    clock.advance(0.2)                 # age 1.1 >= timeout_s
    assert det.poll() == [5]
    assert det.kind_of[5] == "sigkill"
    assert det.poll() == []            # verdicts are reported once


def test_hang_needs_the_longer_grace_window():
    clock, det = _detector()
    det.mark_hung(2)
    clock.advance(1.5)                 # past timeout_s, inside grace
    det.heartbeat()
    assert det.poll() == []
    clock.advance(0.6)                 # age 2.1 >= timeout_s * grace
    det.heartbeat()
    assert det.poll() == [2]
    assert det.kind_of[2] == "hang"


def test_no_mass_suspicion_without_heartbeat_traffic():
    # No heartbeat round has ever run: silence carries no signal, so only
    # explicit unreachability may be suspected (unit tests and cold starts
    # must not see the whole world suspected at once).
    clock = SimClock()
    det = FailureDetector(8, clock)
    det.mark_unreachable(5)
    clock.advance(5.0)
    assert det.poll() == [5]


def test_false_suspicion_and_reintegration():
    clock, det = _detector()
    det.suppress_heartbeats(3, until=3.0)
    for _ in range(4):
        clock.advance(0.5)
        det.heartbeat()
    assert det.poll() == [3]           # healthy rank wrongly suspected
    assert det.kind_of[3] == "suspect"
    det.mark_reachable(3)              # rejoin clears every suspicion bit
    assert det.poll() == []
    clock.advance(0.5)
    det.heartbeat()
    assert det.poll() == []


def test_partition_heal_before_verdict_leaves_no_suspicion():
    clock, det = _detector()
    det.partition([4, 5])
    clock.advance(1.0)
    det.heartbeat()
    assert det.poll() == []            # still inside the grace window
    det.heal()
    clock.advance(1.5)
    det.heartbeat()
    assert det.poll() == []            # silence ended before suspicion
    det.partition([4, 5])
    for _ in range(3):                 # heartbeats keep flowing elsewhere
        clock.advance(0.7)
        det.heartbeat()
    assert sorted(det.poll()) == [4, 5]
    assert det.kind_of[4] == "partition"


def test_jitter_can_cross_the_suspicion_window():
    clock, det = _detector(jitter_s=3.0)
    clock.advance(0.5)
    det.heartbeat()
    clock.advance(0.1)
    # some rank's deterministic jitter pushes its recorded heartbeat far
    # enough into the past to cross the window: a built-in false positive
    fired = det.poll()
    assert fired and all(det.kind_of[r] == "suspect" for r in fired)


# ---------------------------------------------------------------------------
# Detection latency differs by failure kind (satellite 1)
# ---------------------------------------------------------------------------

def _first_detect_span(res):
    spans = [sp for sp in res.spans if sp["phase"] == "detect"]
    assert spans, "no detect span recorded"
    return spans[0]


def test_sigkill_vs_hang_detect_span_durations():
    """The detect span reports the real measured heartbeat age: a hang
    (discovered only via the grace window) must show a measurably longer
    detect duration than a SIGKILL of the same schedule shape."""
    kill = Scenario(name="tmp_sigkill", description="",
                    schedule="@1.0 fail 2", world=8)
    d_kill = _first_detect_span(run_scenario(kill))["duration_s"]
    d_hang = _first_detect_span(run_scenario("hang_detection"))["duration_s"]
    assert d_kill >= 1.0                       # at least the timeout
    assert d_hang >= d_kill + 0.5, (d_kill, d_hang)


# ---------------------------------------------------------------------------
# Topology-aware placement + repair
# ---------------------------------------------------------------------------

def test_placement_replica_host_anti_affinity():
    topo = FaultDomainTree(world=8, ranks_per_host=2, hosts_per_switch=2)
    res = eplb_place(4, 8, 2, np.ones(8, bool), topology=topo)
    assert not res.infeasible
    for e, slots in res.replicas.items():
        hosts = {topo.host_of(s // 2) for s in slots}
        assert len(hosts) >= 2, (e, slots)     # never all on one host


def test_placement_anti_affinity_falls_back_when_survivors_force_it():
    # only host 0 (+ one rank of host 1) survives: coverage must still win
    topo = FaultDomainTree(world=8, ranks_per_host=2, hosts_per_switch=2)
    active = np.zeros(8, bool)
    active[[0, 1, 2]] = True
    res = eplb_place(4, 8, 2, active, topology=topo)
    assert not res.infeasible
    assert all(len(v) >= 1 for v in res.replicas.values())


def test_repair_prefers_proximate_tier2_source():
    topo = FaultDomainTree(world=8, ranks_per_host=2, hosts_per_switch=2)
    old = np.array([5, 7, 0, 1, 2, 3, 7, 4], np.int32)   # expert 7 @ ranks 1,6
    new = old.copy()
    new[0] = 7                                           # dst rank 0 (host 0)
    plan = plan_repair(old, new, np.ones(8, bool), 1, topology=topo)
    assert (0, 1) in plan.tier2        # same-host source beats cross-switch
    plan_flat = plan_repair(old, new, np.ones(8, bool), 1)
    assert any(d == 0 for d, _ in plan_flat.tier2)


# ---------------------------------------------------------------------------
# Fencing, partitions, graceful degradation (e2e scenarios)
# ---------------------------------------------------------------------------

def test_false_suspicion_fences_then_rejoins_with_zero_client_errors():
    """A wrongly-fenced healthy rank costs a bounded pause, never an
    error: the fence event is recorded, the rank reintegrates through the
    normal rejoin path, and every client stream is clean."""
    res = run_scenario("false_suspicion_fence")
    assert res.fences >= 1
    assert res.recoveries >= 1 and res.joins >= 1
    assert res.final_active_fraction == 1.0
    assert res.requests_failed == 0
    assert res.client["error_events"] == 0
    assert not res.stream_violations
    fence = next(e for e in res.timeline if e["kind"] == "fence")
    assert fence["detail"]["kind"] == "suspect"
    assert fence["detail"]["epoch"] >= 1


def test_switch_partition_fences_and_heals_in_one_batch():
    res = run_scenario("switch_partition_heal")
    assert res.partitions >= 1 and res.heals >= 1
    assert res.fences >= 1                       # partitioned side fenced
    assert res.final_active_fraction == 1.0      # healed side back in
    assert not res.stream_violations
    heal = next(e for e in res.timeline if e["kind"] == "heal_batch")
    assert len(heal["detail"]["ranks"]) >= 2     # ONE batched reintegration


def test_epoch_never_regresses_across_partition_heal_interleavings():
    """Deterministic enumeration (always runs): shift the heal across the
    detection/shrink/rejoin boundary and assert the fence (epoch) stays
    strictly monotonic and the world converges back to full strength."""
    for heal_t in (3.0, 8.0, 14.0):
        scn = Scenario(
            name=f"tmp_part_heal_{heal_t:g}", description="",
            schedule=f"@2.0 partition 4 5\n@{heal_t:g} heal",
            world=8, horizon_s=heal_t + 14.0)
        res = run_scenario(scn)
        epochs = [e["detail"]["epoch"] for e in res.timeline
                  if e["kind"] == "membership_commit"]
        assert epochs == sorted(epochs) and len(set(epochs)) == len(epochs), \
            (heal_t, epochs)
        assert res.final_active_fraction == 1.0, heal_t
        assert not res.validity_violations, (heal_t,
                                             res.validity_violations[:3])


def test_epoch_monotonic_property_hypothesis():
    pytest.importorskip(
        "hypothesis", reason="dev extra not installed: pip install -e .[dev]")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=5, deadline=None)
    @given(part_t=st.sampled_from([1.5, 2.5]),
           heal_t=st.sampled_from([4.0, 9.0]),
           target=st.sampled_from(["4 5", "switch:1"]))
    def prop(part_t, heal_t, target):
        scn = Scenario(
            name="tmp_prop", description="",
            schedule=f"@{part_t:g} partition {target}\n@{heal_t:g} heal",
            world=8, horizon_s=heal_t + 14.0)
        res = run_scenario(scn)
        epochs = [e["detail"]["epoch"] for e in res.timeline
                  if e["kind"] == "membership_commit"]
        assert epochs == sorted(epochs) and len(set(epochs)) == len(epochs)
        assert res.final_active_fraction == 1.0

    prop()


def test_coverage_loss_degrades_gracefully():
    """Losing two of three hosts makes shrink impossible: the engine keeps
    stepping (no crash), in-flight work gets FAILED(final=true), new
    submits get structured REJECTED, and the streams stay well-formed."""
    res = run_scenario("coverage_loss_graceful")
    assert res.coverage_loss_events
    assert res.sim_duration_s >= 11.0            # kept stepping to horizon
    assert res.requests_failed >= 1              # in-flight: FAILED final
    assert res.requests_rejected >= 1            # new submits: REJECTED
    ev = res.client["events"]
    assert ev.get("FAILED", 0) >= 1 and ev.get("REJECTED", 0) >= 1
    assert not res.stream_violations
    assert res.tokens_out > 0                    # served until the loss


def test_host_failure_is_one_composed_shrink():
    res = run_scenario("host_failure")
    assert res.recoveries == 1                   # the whole host in ONE saga
    assert res.final_active_fraction == 1.0
    assert res.min_live_replicas >= 1            # anti-affinity paid off
    failed = [e for e in res.injected if e["kind"] == "sigkill"]
    assert failed and len(failed[0]["ranks"]) == 2


# ---------------------------------------------------------------------------
# Degraded frontend + fence epoch check (unit level)
# ---------------------------------------------------------------------------

def _frontend(world=8, spr=1, topology=None):
    cfg = get_config("mixtral-8x22b").reduced()
    table = make_initial_membership(world, cfg.moe.num_experts, spr,
                                    topology=topology)
    params = init_params(cfg, jax.random.key(0), jnp.float32,
                         table.slot_to_expert, table.num_slots)
    rt = ElasticEPRuntime(cfg, params, table,
                          warmup_model=WarmupCostModel(1, 1, 2, 1))
    eng = ServingEngine(rt, max_batch=4, max_len=48)
    return rt, eng, ServingFrontend(eng)


def test_degraded_engine_rejects_submits_with_structured_event():
    rt, eng, fe = _frontend(world=6, spr=1)
    h0 = fe.submit([1, 2, 3], max_new=8)
    for _ in range(3):
        fe.step()
    for r in range(1, 5):
        rt.detector.mark_unreachable(r)          # 2 slots < 4 experts
    rt.clock.advance(1.5)
    for _ in range(4):
        fe.step()
    assert eng.degraded and "slots" in eng.degraded_reason
    assert h0.done and h0.outcome == "FAILED"
    assert h0.events[-1].detail["final"] is True
    h1 = fe.submit([1, 2, 3], max_new=8)
    assert h1.done and h1.outcome == "REJECTED"
    assert h1.events[-1].detail["reason"] == "coverage_loss"
    assert not fe.stream_violations()


def test_fence_rejects_late_writes_from_stale_epoch():
    """The epoch bump IS the fence: the fenced side still lives at the
    pre-fence epoch, and any admission it attempts on a post-fence
    continuation snapshot dies on the scheduler's epoch check."""
    rt, eng, fe = _frontend()
    fe.submit([1, 2, 3], max_new=8)
    fe.step()
    stale_epoch = rt.epoch
    rt.detector.suppress_heartbeats(3, until=6.0)
    for _ in range(4):                   # healthy ranks keep heartbeating
        rt.clock.advance(0.7)            # only rank 3's silence accumulates
        fe.step()
    assert rt.fence_events and rt.fence_events[0]["rank"] == 3
    assert rt.fence_events[0]["kind"] == "suspect"
    assert rt.epoch > stale_epoch                # the fence moved the epoch
    from repro.serving.request import Request
    late = Request(rid=10_000, prompt=[1], max_new_tokens=4)
    late.snapshot_epoch = rt.epoch               # snapshot under the fence
    eng.sched.submit(late)
    with pytest.raises(RuntimeError, match="older membership epoch"):
        eng.sched.admit(epoch=stale_epoch)


# ---------------------------------------------------------------------------
# Admin surface (satellite 6)
# ---------------------------------------------------------------------------

def test_admin_status_and_incidents_expose_robustness_state():
    topo = FaultDomainTree(world=8, ranks_per_host=2, hosts_per_switch=2)
    rt, eng, fe = _frontend(topology=topo)
    fe.submit([1, 2, 3], max_new=8)
    fe.step()
    rt.detector.suppress_heartbeats(3, until=6.0)
    for _ in range(4):                   # healthy ranks keep heartbeating
        rt.clock.advance(0.7)            # only rank 3's silence accumulates
        fe.step()
    resp = fe.admin.execute({"cmd": "status"})
    resp = json.loads(json.dumps(resp))          # must round-trip as JSON
    assert resp["ok"] is True
    status = resp["result"]
    assert status["topology"]["ranks_per_host"] == 2
    assert status["topology"]["hosts"]["1"] == [2, 3]
    assert status["fences"] >= 1
    sus = status["suspicion"]["ranks"]["3"]
    assert sus["kind"] == "suspect"
    assert status["degraded"] is False
    inc = json.loads(fe.admin.execute_json(json.dumps({"cmd": "incidents"})))
    assert inc["ok"] is True
    fences = inc["result"]["fences"]
    assert fences and fences[0]["rank"] == 3
    assert fences[0]["epoch"] >= 1
