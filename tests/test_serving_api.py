"""Client-session serving API (repro.serving.api / repro.serving.events):

  * continuation semantics — on the single-rank-failure scenario under
    ElasticPolicy, ``SchedulerStats.failed == 0`` and ZERO client-visible
    error events (streams show only bounded STALL/RESUMED), while
    FullRestartPolicy still reports failed/retried requests; the compiled
    serve step never recompiles across the whole fail -> recover ->
    rejoin lifetime;
  * stream-ordering invariants — every stream delivers each token index
    exactly once, in order, with no events after a terminal event, across
    fail, drain and rejoin (deterministic sweep of the full registry in
    test_scenarios.py via ``invariants_ok``; a hypothesis property here
    samples registry x dispatch-mode cells);
  * the satellites — submit-time KV overflow guard, queue-depth admission
    control, cancel() from every live state, deadlines, the AdminGateway
    JSON protocol, and the idle-drain termination fix (a driver-scheduled
    future transition must keep the run loop alive).
"""
import json

import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import make_initial_membership
from repro.core.reintegration import WarmupCostModel
from repro.core.scenarios import list_scenarios
from repro.models import init_params
from repro.runtime.elastic import ElasticEPRuntime
from repro.runtime.scenario_runner import run_scenario
from repro.serving.api import ServingFrontend
from repro.serving.engine import ServingEngine
from repro.serving.events import EVENT_KINDS, StreamEvent, validate_stream
from repro.serving.kv_cache import KVCacheManager
from repro.serving.request import Request, RequestState
from repro.serving.scheduler import Scheduler


def _frontend(world=8, spr=1, seed=0, max_batch=4, max_len=64,
              fixed_membership=False, max_queue_depth=None):
    cfg = get_config("mixtral-8x22b").reduced()   # 4 experts, top-2
    table = make_initial_membership(world, cfg.moe.num_experts, spr)
    params = init_params(cfg, jax.random.key(seed), jnp.float32,
                         table.slot_to_expert, table.num_slots)
    rt = ElasticEPRuntime(cfg, params, table,
                          warmup_model=WarmupCostModel(1, 1, 2, 1))
    eng = ServingEngine(rt, max_batch=max_batch, max_len=max_len,
                        fixed_membership=fixed_membership)
    return rt, eng, ServingFrontend(eng, max_queue_depth=max_queue_depth)


def _kinds(handle):
    return [e.kind for e in handle.events]


# ---------------------------------------------------------------------------
# Continuation semantics (the tentpole contract)
# ---------------------------------------------------------------------------

def test_single_failure_continuation_no_client_visible_errors():
    """The acceptance criterion: a rank fault under ElasticPolicy is a
    bounded stall — zero failed requests, zero error events, exactly-once
    token delivery, one compiled step across fail/recover/rejoin."""
    rt, eng, fe = _frontend()
    handles = [fe.submit([3, 1, 4], max_new=40) for _ in range(4)]
    rt.injector.inject_at(1.0, [3])
    fe.run(until=200.0, max_steps=20_000)

    st = eng.sched.stats
    assert st.finished == 4
    assert st.failed == 0 and st.retried == 0 and st.dropped == 0
    assert st.suspended == 4 and st.resumed == 4
    assert st.tokens_recomputed > 0          # the continuation paid replay
    assert fe.metrics()["error_events"] == 0
    assert not fe.stream_violations()
    assert eng.compile_count() == 1
    assert rt.table.active_mask.all()        # casualty rejoined

    for h in handles:
        kinds = _kinds(h)
        assert kinds.count("STALL_BEGIN") == 1
        assert kinds.count("RESUMED") == 1
        assert kinds.count("STALL_END") == 1
        assert "FAILED" not in kinds and "REJECTED" not in kinds
        assert kinds[-1] == "FINISHED"
        # tokens exactly once, in order
        assert [e.index for e in h.events if e.kind == "TOKEN"] \
            == list(range(40))
        # the stall is bracketed: STALL_BEGIN < RESUMED <= STALL_END
        order = {k: kinds.index(k)
                 for k in ("STALL_BEGIN", "RESUMED", "STALL_END")}
        assert order["STALL_BEGIN"] < order["RESUMED"] <= order["STALL_END"]


def test_resume_validates_snapshot_epoch_against_membership_version():
    rt, eng, fe = _frontend()
    handles = [fe.submit([3, 1, 4], max_new=40) for _ in range(4)]
    rt.injector.inject_at(1.0, [3])
    fe.run(until=200.0, max_steps=20_000)
    for h in handles:
        resumed = [e for e in h.events if e.kind == "RESUMED"]
        assert len(resumed) == 1
        ev = resumed[0]
        # suspended under the post-shrink epoch, resumed at a version that
        # is never older than the snapshot
        assert ev.detail["epoch"] >= ev.detail["snapshot_epoch"] >= 0
        assert ev.detail["recomputed"] == \
            next(e for e in h.events if e.kind == "STALL_BEGIN"
                 ).detail["progress"]


def test_baseline_full_restart_still_fails_and_retries():
    """FullRestartPolicy keeps the paper's §3.1 contrast honest: clients
    see explicit FAILED events and the request recomputes from scratch —
    but the stream stays exactly-once (duplicates suppressed)."""
    rt, eng, fe = _frontend(fixed_membership=True)
    handles = [fe.submit([3, 1, 4], max_new=40) for _ in range(4)]
    rt.injector.inject_at(1.0, [3])
    fe.run(until=600.0, max_steps=30_000)

    st = eng.sched.stats
    assert st.finished == 4
    assert st.failed == 4 and st.retried == 4
    assert st.suspended == 0 and st.resumed == 0
    assert fe.metrics()["error_events"] == 4
    assert not fe.stream_violations()
    assert eng.compile_count() == 1
    for h in handles:
        kinds = _kinds(h)
        assert "FAILED" in kinds and "STALL_BEGIN" not in kinds
        failed = next(e for e in h.events if e.kind == "FAILED")
        assert failed.detail["final"] is False
        assert [e.index for e in h.events if e.kind == "TOKEN"] \
            == list(range(40))
        assert h.suppressed > 0              # recomputed prefix never re-sent


def test_baseline_double_fault_mid_replay_keeps_stream_well_formed():
    """A second fault landing while a baseline request is still replaying
    its suppressed prefix emits a second non-final FAILED inside the open
    stall window — that is a legal window extension (the client sees every
    error), not a nesting violation, and the stream stays exactly-once."""
    rt, eng, fe = _frontend(fixed_membership=True)
    handles = [fe.submit([1, 2], max_new=40) for _ in range(2)]
    for _ in range(10):
        fe.step()
    assert all(h.delivered > 0 for h in handles)
    rt.injector.inject_at(rt.clock.now() + 0.01, [3])
    for _ in range(30):                      # first restart + replay begins
        fe.step()
    rt.injector.inject_at(rt.clock.now() + 0.01, [5])
    fe.run(until=rt.clock.now() + 900.0, max_steps=40_000)
    assert eng.sched.stats.failed >= 4       # both requests, both faults
    assert not fe.stream_violations()
    for h in handles:
        assert h.outcome == "FINISHED"
        assert [e.index for e in h.events if e.kind == "TOKEN"] \
            == list(range(40))
    # both stall windows (one per fault batch) are counted client-side
    assert fe.metrics()["stall_events"] >= 2


def test_deadline_is_relative_to_submit_time():
    """deadline= is sim-seconds FROM SUBMIT, not an absolute clock value:
    a request submitted late in a run must get its full budget."""
    rt, eng, fe = _frontend(max_batch=2)
    first = fe.submit([1] * 4, max_new=8)
    fe.run(max_steps=200)
    assert first.outcome == "FINISHED"
    assert rt.clock.now() > 0.2
    late = fe.submit([1] * 4, max_new=8, deadline=60.0)
    fe.run(max_steps=400)
    assert late.outcome == "FINISHED"        # not instantly expired
    assert not fe.stream_violations()


def test_drain_preemption_is_not_an_error():
    """A planned drain preempts in-flight streams: PREEMPTED/RESUMED with
    progress kept, zero error events, and the preempted work finishes."""
    rt, eng, fe = _frontend()
    handles = [fe.submit([1] * 6, max_new=40) for _ in range(4)]
    for _ in range(8):
        fe.step()
    assert eng.sched.inflight > 0
    fe.admin.execute({"cmd": "drain", "ranks": [2]})
    fe.run(until=rt.clock.now() + 120.0, max_steps=20_000)

    st = eng.sched.stats
    assert st.preempted == 4 and st.failed == 0
    assert st.finished == 4
    assert fe.metrics()["error_events"] == 0
    assert not fe.stream_violations()
    for h in handles:
        kinds = _kinds(h)
        assert "PREEMPTED" in kinds and "FAILED" not in kinds
        assert next(e for e in h.events if e.kind == "PREEMPTED"
                    ).detail["cause"] == "drain"


# ---------------------------------------------------------------------------
# Satellites: admission control, overflow guard, cancel, deadlines
# ---------------------------------------------------------------------------

def test_overflow_rejected_at_submit_with_structured_event():
    """prompt + max_new that cannot fit max_len is refused at submit with
    a structured REJECTED event — never queued, never silently overflowing
    slot length bookkeeping mid-decode."""
    rt, eng, fe = _frontend(max_len=32)
    h = fe.submit([1] * 8, max_new=64)       # 8 + 64 > 32
    assert h.done and h.outcome == "REJECTED"
    ev = h.events[0]
    assert ev.detail["reason"] == "overflow"
    assert ev.detail == {"reason": "overflow", "context_len": 8,
                         "max_new": 64, "max_len": 32}
    assert eng.sched.stats.rejected == 1
    assert not eng.sched.queue               # never entered the queue
    # a fitting request on the same frontend is unaffected
    ok = fe.submit([1] * 8, max_new=16)
    fe.run(max_steps=200)
    assert ok.outcome == "FINISHED"
    assert not fe.stream_violations()


def test_scheduler_submit_returns_false_on_overflow():
    kv = KVCacheManager(num_slots=2, max_len=16)
    sched = Scheduler(kv)
    assert sched.submit(Request(rid=0, prompt=[1] * 4,
                                max_new_tokens=100)) is False
    assert sched.stats.rejected == 1
    assert sched.submit(Request(rid=1, prompt=[1] * 4,
                                max_new_tokens=12)) is True
    # allocate refuses a can-never-fit sequence loudly (the guard that
    # used to be a silent overflow)
    with pytest.raises(ValueError):
        kv.allocate(9, context_len=4, reserve=100)


def test_queue_depth_admission_control():
    rt, eng, fe = _frontend(max_batch=2, max_queue_depth=2)
    handles = [fe.submit([1, 2], max_new=4) for _ in range(6)]
    rejected = [h for h in handles if h.outcome == "REJECTED"]
    assert len(rejected) == 4                # 2 queued, rest refused
    assert all(h.events[0].detail["reason"] == "queue_full"
               for h in rejected)
    assert fe.rejected_admission == 4
    fe.run(max_steps=500)
    assert sum(h.outcome == "FINISHED" for h in handles) == 2
    assert not fe.stream_violations()


def test_cancel_from_queued_decoding_and_stalled_states():
    rt, eng, fe = _frontend(max_batch=2)
    # 3 submits on a 2-slot engine: rid 2 stays QUEUED
    handles = [fe.submit([1] * 4, max_new=60) for _ in range(3)]
    for _ in range(6):
        fe.step()
    assert handles[2].delivered == 0
    # (1) cancel from QUEUED
    assert handles[2].cancel()
    # (2) cancel from DECODING: slot must be released
    free_before = eng.kv.stats()["slots_free"]
    assert handles[0].cancel()
    assert eng.kv.stats()["slots_free"] == free_before + 1
    # (3) cancel from STALLED: suspend rid 1 via a fault, then cancel
    # before it resumes
    rt.detector.mark_unreachable(3)
    rt.clock.advance(1.5)
    eng.sched.suspend_inflight(now=rt.clock.now(), cause="fault",
                               epoch=rt.epoch)
    req1 = next(r for r in eng.sched.queue if r.rid == 1)
    assert req1.state == RequestState.STALLED
    assert handles[1].cancel()
    assert eng.sched.stats.cancelled == 3
    for h in handles:
        assert h.outcome == "CANCELLED"
    # idempotent: a second cancel is a no-op
    assert handles[0].cancel() is False
    assert eng.sched.stats.cancelled == 3
    # the engine keeps stepping fine with everything cancelled
    fe.run(max_steps=2000)
    assert not fe.stream_violations()


def test_deadline_expires_as_cancellation():
    rt, eng, fe = _frontend(max_batch=2)
    slow = fe.submit([1] * 4, max_new=50, deadline=1.0)
    fast = fe.submit([1] * 4, max_new=4)
    fe.run(until=10.0, max_steps=2000)
    assert slow.outcome == "CANCELLED"
    assert next(e for e in slow.events if e.kind == "CANCELLED"
                ).detail["cause"] == "deadline"
    assert fast.outcome == "FINISHED"
    assert not fe.stream_violations()


# ---------------------------------------------------------------------------
# AdminGateway: JSON command/response protocol
# ---------------------------------------------------------------------------

def test_admin_gateway_json_round_trip_and_errors():
    rt, eng, fe = _frontend()
    gw = fe.admin
    # string in / string out, round-trips through json
    raw = gw.execute_json('{"cmd": "status"}')
    resp = json.loads(raw)
    assert resp["ok"] and resp["cmd"] == "status"
    st = resp["result"]
    assert st["policy"] == "elastic" and st["world"] == 8
    assert st["active_ranks"] == list(range(8))
    assert st["version"] == st["epoch"] == rt.epoch
    assert json.loads(json.dumps(resp)) == resp
    # epoch + incidents queries
    assert gw.execute({"cmd": "epoch"})["result"]["version"] == rt.epoch
    inc = gw.execute({"cmd": "incidents", "last": 5})
    assert inc["ok"] and isinstance(inc["result"]["events"], list)
    # malformed commands come back as error responses, never raises
    assert not gw.execute('{"cmd": "explode"}')["ok"]
    assert not gw.execute('not json')["ok"]
    assert not gw.execute({"cmd": "drain"})["ok"]              # no ranks
    assert not gw.execute({"cmd": "drain", "ranks": [99]})["ok"]
    assert not gw.execute({"cmd": "drain", "ranks": [1],
                           "at": -5.0})["ok"]                  # in the past
    assert rt.epoch == json.loads(raw)["epoch"]                # no mutation


def test_admin_gateway_drives_control_plane_transitions():
    rt, eng, fe = _frontend()
    for _ in range(4):
        fe.submit([1, 2], max_new=8)
    e0 = rt.epoch
    resp = fe.admin.execute({"cmd": "scale_down", "ranks": [6, 7]})
    assert resp["ok"] and resp["result"]["requested"]
    fe.run(until=30.0, max_steps=5000)
    assert rt.epoch > e0
    assert not rt.table.entries[6].active and not rt.table.entries[7].active
    status = fe.admin.execute({"cmd": "status"})["result"]
    assert status["active_ranks"] == list(range(6))
    resp = fe.admin.execute({"cmd": "scale_up", "ranks": [6, 7]})
    assert resp["ok"]
    fe.run(until=rt.clock.now() + 60.0, max_steps=5000)
    assert rt.table.active_mask.all()
    assert eng.compile_count() == 1


def test_idle_run_waits_for_scheduled_admin_ops():
    """The ride-along fix: with NO client work at all, a driver-scheduled
    future drain/undrain pair must still fire — the old engine idle-break
    exited before the clock ever reached it."""
    rt, eng, fe = _frontend()
    drain = fe.admin.execute({"cmd": "drain", "ranks": [2], "at": 5.0})
    undrain = fe.admin.execute({"cmd": "undrain", "ranks": [2], "at": 12.0})
    assert drain["ok"] and drain["result"]["scheduled"]
    assert undrain["ok"]
    assert fe.admin.execute({"cmd": "status"})["result"]["pending_admin"] == 2
    fe.run(max_steps=5000)                   # until=None: idle-stop path
    kinds = [e.kind for e in rt.timeline]
    assert "drain" in kinds and "undrain" in kinds
    assert rt.table.active_mask.all()
    assert rt.clock.now() >= 12.0
    # and with nothing pending the loop still terminates promptly
    t = rt.clock.now()
    fe.run(max_steps=5000)
    assert rt.clock.now() == t


# ---------------------------------------------------------------------------
# Stream-ordering property over the scenario registry
# ---------------------------------------------------------------------------

def test_validate_stream_catches_violations():
    def ev(kind, t, seq, index=-1, **detail):
        return StreamEvent(kind=kind, t=t, seq=seq, index=index,
                           detail=detail)
    assert validate_stream([]) == []
    ok = [ev("TOKEN", 0.1, 0, 0), ev("STALL_BEGIN", 0.2, 1, cause="fault"),
          ev("RESUMED", 0.3, 2, epoch=3), ev("STALL_END", 0.4, 3),
          ev("TOKEN", 0.4, 4, 1), ev("FINISHED", 0.5, 5)]
    assert validate_stream(ok) == []
    # duplicated index
    assert validate_stream([ev("TOKEN", 0.1, 0, 0), ev("TOKEN", 0.2, 1, 0)])
    # out-of-order index
    assert validate_stream([ev("TOKEN", 0.1, 0, 1)])
    # events after terminal
    assert validate_stream([ev("FINISHED", 0.1, 0), ev("TOKEN", 0.2, 1, 0)])
    # token inside an open stall window
    assert validate_stream([ev("STALL_BEGIN", 0.1, 0, cause="fault"),
                            ev("TOKEN", 0.2, 1, 0)])
    # nested openers / dangling closers
    assert validate_stream([ev("STALL_BEGIN", 0.1, 0), ev("PREEMPTED", 0.2, 1)])
    assert validate_stream([ev("STALL_END", 0.1, 0)])
    assert validate_stream([ev("RESUMED", 0.1, 0)])
    # time going backwards / bad seq / unknown kind
    assert validate_stream([ev("TOKEN", 0.5, 0, 0), ev("TOKEN", 0.1, 1, 1)])
    assert validate_stream([ev("TOKEN", 0.1, 7, 0)])
    assert validate_stream([ev("NOPE", 0.1, 0)])
    # non-final FAILED opens a stall window; final FAILED is terminal
    retry = [ev("TOKEN", 0.1, 0, 0), ev("FAILED", 0.2, 1, final=False),
             ev("STALL_END", 0.3, 2), ev("TOKEN", 0.3, 3, 1),
             ev("FAILED", 0.4, 4, final=True)]
    assert validate_stream(retry) == []
    assert validate_stream(retry + [ev("TOKEN", 0.5, 5, 2)])
    # a second non-final FAILED inside the open window EXTENDS it (legal:
    # back-to-back baseline restarts), but a stall marker nesting is not
    double_fail = [ev("FAILED", 0.1, 0, final=False),
                   ev("FAILED", 0.2, 1, final=False),
                   ev("STALL_END", 0.3, 2), ev("TOKEN", 0.3, 3, 0),
                   ev("FINISHED", 0.4, 4)]
    assert validate_stream(double_fail) == []
    assert validate_stream([ev("FAILED", 0.1, 0, final=False),
                            ev("STALL_BEGIN", 0.2, 1)])


def test_stream_invariants_hold_across_registry_property():
    """Hypothesis property over the full scenario registry (both dispatch
    modes): every stream delivers each token index exactly once, in order,
    with no events after a terminal event — across fail, drain and rejoin.
    (The deterministic full sweep rides test_scenarios.py through
    ``invariants_ok``, which now includes stream violations; here
    hypothesis varies the registry cell and the seed.)"""
    pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    cells = [(name, mode) for name in list_scenarios()
             for mode in ("dense", "ragged")]

    @settings(max_examples=6, deadline=None,
              suppress_health_check=[HealthCheck.too_slow,
                                     HealthCheck.data_too_large])
    @given(cell=st.sampled_from(cells), seed=st.integers(0, 3))
    def prop(cell, seed):
        name, mode = cell
        res = run_scenario(name, seed=seed, dispatch=mode)
        assert not res.stream_violations, \
            (name, mode, seed, res.stream_violations[:3])
        ev = res.client["events"]
        assert set(ev) <= set(EVENT_KINDS)
        # elastic continuation: a fault or planned transition never shows
        # the client an error event
        assert res.client["error_events"] == 0, (name, mode, seed)
        assert res.requests_failed == 0, (name, mode, seed)

    prop()


def test_runner_exposes_client_metrics_and_baseline_contrast():
    """One registry scenario end-to-end through the runner: the elastic
    run reports suspended-but-never-failed with client metrics attached;
    the fixed-membership baseline still reports failed/retried."""
    res = run_scenario("concurrent_multi_failure")
    assert res.requests_failed == 0 and res.requests_suspended > 0
    assert res.client["error_events"] == 0
    assert res.client["stall_events"] > 0
    assert res.client["tokens_recomputed"] > 0
    assert res.client["ttft_p50_s"] > 0
    assert res.client["stall_p99_s"] > 0
    assert res.client["goodput_tok_s"] > 0
    assert res.invariants_ok
    summary = res.summary()
    assert summary["client"]["stall_max_s"] > 0
    assert summary["stream_violations"] == 0
    json.dumps(summary)                      # BENCH row stays serializable

    base = run_scenario("concurrent_multi_failure", fixed_membership=True,
                        check_invariants=False)
    assert base.requests_failed > 0 and base.requests_retried > 0
    assert base.requests_suspended == 0
    assert base.client["error_events"] > 0
    assert not base.stream_violations        # exactly-once even under retry


def test_continuation_preserves_token_values_across_failure():
    """The resumed stream continues from the preserved prefix: tokens
    delivered before the fault keep their values (never re-sent), and the
    engine's compiled step replays the prefix through chunk-1 prefill."""
    rt, eng, fe = _frontend()
    h = fe.submit([3, 1, 4], max_new=30)
    for _ in range(12):
        fe.step()
    pre_fault = list(h.tokens)
    assert len(pre_fault) > 3
    rt.injector.inject_at(rt.clock.now() + 0.01, [3])
    fe.run(until=rt.clock.now() + 120.0, max_steps=10_000)
    assert h.outcome == "FINISHED"
    assert h.tokens[:len(pre_fault)] == pre_fault
    assert len(h.tokens) == 30
    assert not validate_stream(h.events)
