"""End-to-end behaviour of the public entry points (the paper's system as a
user sees it): serving driver with failover, elastic properties under
hypothesis-driven failure schedules, and backup-service accounting."""
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="dev extra not installed: pip install -e .[dev]")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, cell_is_supported, get_config, list_configs
from repro.core import BackupStore, make_initial_membership
from repro.core.reintegration import WarmupCostModel
from repro.models import init_params
from repro.runtime.elastic import ElasticEPRuntime
from repro.serving.engine import ServingEngine
from repro.serving.request import Request


def test_all_assigned_archs_registered():
    assert len(list_configs()) == 10
    for n in list_configs():
        cfg = get_config(n)
        assert cfg.param_count() > 0


def test_cell_matrix_covers_40():
    cells = [(a, s) for a in list_configs() for s in SHAPES]
    assert len(cells) == 40
    supported = [c for c in cells
                 if cell_is_supported(get_config(c[0]), SHAPES[c[1]])[0]]
    # 7 documented long_500k skips (see DESIGN.md)
    assert len(supported) == 33


def test_serve_driver_end_to_end(capsys):
    from repro.launch.serve import main
    main(["--arch", "mixtral-8x22b", "--smoke", "--world", "8",
          "--requests", "6", "--prompt-len", "4", "--max-new", "6",
          "--max-batch", "4", "--fail-rank", "2", "--fail-at", "0.5",
          "--until", "80"])
    out = capsys.readouterr().out
    assert "finished=6" in out
    assert "serve-step compilations: 1" in out
    assert "recovery_done" in out and "join" in out


def test_backup_store_accounting():
    bk = BackupStore(num_nodes=3)
    for e in range(7):
        bk.store(e, {"w": np.ones((4, 5), np.float32)})
    assert bk.total_bytes() == 7 * 4 * 5 * 4
    _ = bk.fetch(3)
    _ = bk.fetch(5)
    assert bk.fetch_count == 2
    assert bk.bytes_fetched == 2 * 80
    # experts spread across node managers
    nodes = {bk.node_of(e) for e in range(7)}
    assert len(nodes) == 3


@settings(max_examples=8, deadline=None)
@given(data=st.data())
def test_property_any_survivable_failure_schedule_recovers(data):
    """For random failure schedules that keep coverage feasible, the system
    always returns to a valid state and eventually full capacity."""
    world, spr = 8, 2
    cfg = get_config("mixtral-8x22b").reduced()
    table = make_initial_membership(world, cfg.moe.num_experts, spr)
    params = init_params(cfg, jax.random.key(0), jnp.float32,
                         table.slot_to_expert, table.num_slots)
    rt = ElasticEPRuntime(cfg, params, table,
                          warmup_model=WarmupCostModel(0.5, 0.5, 0.5, 0.5))
    n_events = data.draw(st.integers(1, 3))
    ranks = data.draw(st.permutations(range(world)))
    t = 0.3
    for i in range(n_events):
        rt.injector.inject_at(t, [ranks[i]])
        t += data.draw(st.floats(4.0, 8.0))
    eng = ServingEngine(rt, max_batch=2, max_len=2048)
    eng.sched.submit(Request(rid=0, prompt=[1, 2], max_new_tokens=1500))
    eng.run(until=t + 30.0, max_steps=4000)
    from repro.core.validity import check
    rep = check(rt.table, rt.membership, reachable=rt.detector.reachable)
    assert rep.valid, rep.violations
    assert rt.table.active_mask.all()
    assert eng.compile_count() == 1
