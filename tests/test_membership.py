"""Unit tests: PeerTable, MembershipState, validity contract."""
import numpy as np
import pytest

from repro.core import (
    MembershipState,
    PeerTable,
    check,
    make_initial_membership,
)


def test_initial_membership_covers_all_experts():
    t = make_initial_membership(world=8, num_experts=4, slots_per_rank=2)
    e2s = t.expert_to_slots()
    assert all(len(v) >= 1 for v in e2s.values())
    assert t.num_slots == 16
    # replicas land on distinct ranks (anti-affinity of the stride layout)
    for e, slots in e2s.items():
        ranks = [t.rank_of_slot(s) for s in slots]
        assert len(set(ranks)) == len(ranks)


def test_deactivate_reactivate_bumps_version():
    t = make_initial_membership(4, 4, 1)
    v0 = t.version
    t.deactivate(2)
    assert t.version > v0
    assert not t.entries[2].active
    epoch = t.entries[2].endpoint_epoch
    t.reactivate(2)
    assert t.entries[2].active
    assert t.entries[2].endpoint_epoch == epoch + 1  # metadata re-exchanged


def test_to_device_roundtrip():
    t = make_initial_membership(4, 8, 2)
    ms = t.to_device()
    assert ms.world == 4
    assert ms.num_slots == 8
    assert ms.num_experts == 8
    np.testing.assert_array_equal(np.asarray(ms.slot_to_expert),
                                  t.slot_to_expert)
    assert int(np.asarray(ms.replica_count).min()) >= 1


def test_expert_location_excludes_inactive_ranks():
    t = make_initial_membership(4, 4, 1)
    t.deactivate(0)
    e2s = t.expert_to_slots()
    for e, slots in e2s.items():
        for s in slots:
            assert t.rank_of_slot(s) != 0


def test_validity_contract_detects_each_violation():
    t = make_initial_membership(4, 4, 1)
    ms = t.to_device()
    rep = check(t, ms)
    assert rep.valid

    # 1. peer-set violation: rank marked active but unreachable
    reach = t.active_mask.copy()
    reach[1] = False
    rep = check(t, ms, reachable=reach)
    assert not rep.peer_set_valid

    # 2. coverage violation: kill the only host of expert 2
    t2 = make_initial_membership(4, 4, 1)
    t2.deactivate(2)   # slot 2 held expert 2 (R=1 layout)
    rep2 = check(t2)
    assert not rep2.expert_coverage_valid

    # 3. routing violation: device state stale vs control plane
    t3 = make_initial_membership(4, 4, 1)
    ms3 = t3.to_device()
    t3.deactivate(3)
    rep3 = check(t3, ms3)
    assert not rep3.routing_valid
