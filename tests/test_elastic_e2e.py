"""End-to-end elastic lifecycle: failure -> bounded recovery -> reduced
serving -> deferred-join reintegration, with the paper's key invariants:

  * the serve step NEVER recompiles across membership changes
    (CUDA-graph-stability analogue),
  * model outputs under a repaired degraded placement equal the healthy
    outputs whenever coverage survives (replica consistency),
  * in-flight requests are failed and retried (paper §3.1 semantics),
  * two bounded pauses vs one long restart outage.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import make_initial_membership
from repro.core.reintegration import WarmupCostModel
from repro.models import init_params
from repro.runtime.elastic import ElasticEPRuntime
from repro.serving.engine import FullRestartCostModel, ServingEngine
from repro.serving.request import Request


def _runtime(world=8, spr=1, seed=0, **kw):
    cfg = get_config("mixtral-8x22b").reduced()  # 4 experts, top-2
    table = make_initial_membership(world, cfg.moe.num_experts, spr)
    params = init_params(cfg, jax.random.key(seed), jnp.float32,
                         table.slot_to_expert, table.num_slots)
    return cfg, ElasticEPRuntime(cfg, params, table, **kw)


def test_no_recompile_across_membership_changes():
    cfg, rt = _runtime()
    eng = ServingEngine(rt, max_batch=4, max_len=40)
    for i in range(4):
        eng.sched.submit(Request(rid=i, prompt=[1, 2, 3], max_new_tokens=4))
    rt.injector.inject_at(0.3, [2])
    eng.run(until=50.0, max_steps=1500)
    assert eng.compile_count() == 1
    kinds = [e.kind for e in rt.timeline]
    assert "failure" in kinds and "recovery_done" in kinds and "join" in kinds
    assert rt.table.active_mask.all()      # fully restored


def test_degraded_outputs_match_when_replicas_survive():
    """R=2: one rank failure keeps full coverage; post-repair outputs must be
    NUMERICALLY identical for tokens routed to surviving replicas of the
    same logical experts (replica weight consistency)."""
    cfg, rt = _runtime(world=8, spr=1)   # 8 slots, 4 experts, R=2
    from repro.models import decode_step, init_caches, Deployment
    B = 4
    caches = init_caches(cfg, B, 16, jnp.float32)
    toks = jnp.ones((B, 1), jnp.int32)
    lengths = jnp.zeros((B,), jnp.int32)
    y0, _ = decode_step(cfg, rt.params, toks, lengths, caches, rt.membership,
                        rt.dpl)

    rt.detector.mark_unreachable(5)
    rt.clock.advance(2.0)
    failed = rt.poll_failures()
    assert failed == [5]
    rt.handle_failure(failed)

    y1, _ = decode_step(cfg, rt.params, toks, lengths, caches, rt.membership,
                        rt.dpl)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=1e-4,
                               atol=1e-4)


def test_recovery_phases_bounded():
    cfg, rt = _runtime(world=16, spr=2)
    rt.detector.mark_unreachable(3)
    rt.detector.mark_unreachable(7)
    rt.clock.advance(1.5)
    phases = rt.handle_failure(rt.poll_failures())
    assert 0 < phases["total"] < 30.0      # paper: 6-21 s at these scales
    ev = [e for e in rt.timeline if e.kind == "recovery_done"][0]
    mix = ev.detail["mix"]
    assert sum(mix.values()) > 0


def test_inflight_requests_suspended_and_resumed_not_failed():
    """Elastic fault semantics are continuation, not retry: in-flight work
    is suspended with its generated prefix intact (epoch-tagged snapshot)
    and resumed through the chunk-1 replay path — clients never see a
    failure. (The fixed-membership baseline keeps fail-and-retry:
    tests/test_serving_api.py.)"""
    cfg, rt = _runtime()
    eng = ServingEngine(rt, max_batch=4, max_len=64)
    for i in range(4):
        eng.sched.submit(Request(rid=i, prompt=[1] * 8, max_new_tokens=30))
    # fail while decodes are definitely in flight
    for _ in range(5):
        eng.step()
    assert eng.sched.inflight > 0
    progress = {r.rid: len(r.generated) for r in eng.sched.running.values()}
    rt.injector.inject_at(rt.clock.now(), [1])
    rt.clock.advance(1.2)
    eng.step()
    assert eng.sched.stats.suspended > 0
    assert eng.sched.stats.failed == 0 and eng.sched.stats.retried == 0
    eng.run(until=rt.clock.now() + 100.0, max_steps=3000)
    assert eng.sched.stats.finished == 4   # clients eventually served
    assert eng.sched.stats.resumed == eng.sched.stats.suspended
    # the replay recomputed exactly the preserved prefixes
    assert eng.sched.stats.tokens_recomputed == sum(progress.values())


def test_two_bounded_pauses_vs_full_restart():
    """The Fig. 1 structure: EEP = two short pauses with a productive plateau
    between; fixed membership = one long outage."""
    warm = WarmupCostModel(process_relaunch_s=1, runtime_init_s=2,
                           weight_load_s=3, graph_capture_s=2)
    cfg, rt = _runtime(warmup_model=warm)
    eng = ServingEngine(rt, max_batch=4, max_len=256)
    for i in range(24):
        eng.sched.submit(Request(rid=i, prompt=[1] * 4, max_new_tokens=120))
    rt.injector.inject_at(1.0, [4])
    eng.run(until=60.0, max_steps=6000)
    t_rec = [e.t for e in rt.timeline if e.kind == "recovery_done"][0]
    t_fail = [e.t for e in rt.timeline if e.kind == "failure"][0]
    t_join = [e.t for e in rt.timeline if e.kind == "join"][0]
    pause1 = t_rec - t_fail
    assert pause1 < 15.0
    # reduced-capacity plateau: throughput nonzero between pauses
    mid = [s for s in eng.trace if t_rec < s.t < t_join]
    assert any(s.tokens_per_s > 0 for s in mid)
    assert any(abs(s.active_fraction - 7 / 8) < 1e-6 for s in mid)

    # fixed-membership baseline on the same workload
    cfg2, rt2 = _runtime(seed=0)
    eng2 = ServingEngine(rt2, max_batch=4, max_len=256,
                         fixed_membership=True,
                         restart_model=FullRestartCostModel(
                             environment_setup_s=10, model_load_s=20,
                             jit_warmup_s=10, graph_capture_s=8))
    for i in range(24):
        eng2.sched.submit(Request(rid=i, prompt=[1] * 4, max_new_tokens=120))
    rt2.injector.inject_at(1.0, [4])
    eng2.run(until=120.0, max_steps=6000)
    restart = [e for e in rt2.timeline if e.kind == "full_restart_done"][0]
    assert restart.detail["seconds"] == 48.0
    # EEP total off-service << full restart outage
    assert pause1 + 1.0 < restart.detail["seconds"]


def test_repeated_failures_sequential():
    """Multiple distinct failures over time, each recovered, all rejoined."""
    cfg, rt = _runtime(world=8, spr=2,
                       warmup_model=WarmupCostModel(1, 1, 1, 1))
    eng = ServingEngine(rt, max_batch=2, max_len=512)
    eng.sched.submit(Request(rid=0, prompt=[1, 2], max_new_tokens=400))
    rt.injector.inject_at(0.5, [0])
    rt.injector.inject_at(12.0, [6])
    eng.run(until=60.0, max_steps=4000)
    joins = [e for e in rt.timeline if e.kind == "join"]
    assert len(joins) == 2
    assert rt.table.active_mask.all()
    assert eng.compile_count() == 1


def test_straggler_mitigation_shifts_load():
    """A persistently slow (but alive) rank gets de-weighted by the
    capacity-aware EPLB: hot-expert replicas migrate off it, membership and
    compiled step untouched (beyond-paper; see core/straggler.py)."""
    import numpy as np
    cfg, rt = _runtime(world=8, spr=2)
    # expert 0 is hot
    rt.expert_load = np.array([10.0, 1.0, 1.0, 1.0])
    eng = ServingEngine(rt, max_batch=2, max_len=1024)
    eng.sched.submit(Request(rid=0, prompt=[1, 2], max_new_tokens=600))
    rt.rank_slowdown[3] = 3.0          # rank 3 throttles
    eng.run(until=20.0, max_steps=800)
    evs = [e for e in rt.timeline if e.kind == "straggler_mitigation"]
    assert evs and 3 in evs[0].detail["flagged"]
    # hot expert 0 no longer hosted on the straggler
    hosts0 = {rt.table.rank_of_slot(s)
              for s in rt.table.expert_to_slots()[0]}
    assert 3 not in hosts0
    # still a valid instance, same executable, all ranks active
    from repro.core.validity import check
    assert check(rt.table, rt.membership).valid
    assert rt.table.active_mask.all()
    assert eng.compile_count() == 1

    # recovery: rank 3 speeds back up -> flag clears on later steps
    rt.rank_slowdown[3] = 1.0
    eng.sched.submit(Request(rid=1, prompt=[1], max_new_tokens=600))
    eng.run(until=rt.clock.now() + 60.0, max_steps=3000)
    assert 3 not in rt.straggler.flagged
