"""Transactional membership control plane (repro.core.transitions):

  * epoch semantics — epochs strictly increase across EVERY transition kind
    (fault, join batch, drain, undrain, scale down/up, straggler re-place),
    and the device-published ``MembershipState.version`` mirrors the
    committed epoch;
  * abort semantics — a transaction that fails planning or validation
    leaves table/params/membership byte-identical (deterministic + a
    hypothesis property test over random drain sets);
  * the ControlPlane facade (drain/undrain/scale_down/scale_up) and the
    TransitionPolicy selection (elastic vs full-restart baseline);
  * structural enforcement — the runtime and engine sources contain NO
    direct ``set_placement``/``to_device``/validity-check call sites: every
    mutation goes through ``MembershipTransaction.commit``;
  * the satellite fixes: targeted nested-dict copy in
    ``set_moe_slot_leaves``, real tier2/tier3 byte counts in straggler
    telemetry, incident tags on mid-transfer recovery events, and graceful
    preemption (not failure) of in-flight requests on planned drains.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import make_initial_membership
from repro.core.repair import RecoveryCostModel
from repro.core.reintegration import WarmupCostModel
from repro.core.scenarios import Scenario
from repro.core.transitions import (
    ElasticPolicy,
    FullRestartPolicy,
    TransitionAborted,
    TransitionPolicy,
    moe_slot_leaves,
    set_moe_slot_leaves,
)
from repro.models import init_params
from repro.runtime.elastic import ElasticEPRuntime
from repro.runtime.scenario_runner import build_scenario_runtime
from repro.serving.engine import ServingEngine
from repro.serving.request import Request


def _runtime(world=8, spr=2, seed=0, **kw):
    cfg = get_config("mixtral-8x22b").reduced()   # 4 experts, top-2
    table = make_initial_membership(world, cfg.moe.num_experts, spr)
    params = init_params(cfg, jax.random.key(seed), jnp.float32,
                         table.slot_to_expert, table.num_slots)
    return cfg, ElasticEPRuntime(cfg, params, table,
                                 warmup_model=WarmupCostModel(1, 1, 2, 1),
                                 **kw)


def _snapshot(rt):
    return {
        "membership": rt.membership,
        "table": rt.table,
        "params": rt.params,
        "epoch": rt.epoch,
        "active": rt.table.active_mask.copy(),
        "s2e": rt.table.slot_to_expert.copy(),
        "version": rt.table.version,
    }


def _assert_untouched(rt, snap):
    assert rt.membership is snap["membership"]      # never republished
    assert rt.table is snap["table"]                # never swapped
    assert rt.params is snap["params"]              # never swapped
    assert rt.epoch == snap["epoch"]
    np.testing.assert_array_equal(rt.table.active_mask, snap["active"])
    np.testing.assert_array_equal(rt.table.slot_to_expert, snap["s2e"])
    assert rt.table.version == snap["version"]


def _dev_version(rt) -> int:
    return int(np.asarray(rt.membership.version))


# ---------------------------------------------------------------------------
# Epoch semantics
# ---------------------------------------------------------------------------

def test_epoch_strictly_increases_across_every_transition_kind():
    """fault, join, drain, undrain, scale down, scale up, straggler
    re-place: each is exactly one commit, each bumps the epoch, and the
    device-published version mirrors it at every point."""
    cfg, rt = _runtime()
    epochs = [rt.epoch]

    def checkpoint():
        assert rt.epoch > epochs[-1], "epoch must strictly increase"
        assert _dev_version(rt) == rt.epoch, "device version mirrors epoch"
        epochs.append(rt.epoch)

    assert _dev_version(rt) == rt.epoch            # bootstrap commit

    # fault
    rt.detector.mark_unreachable(3)
    rt.clock.advance(1.5)
    rt.handle_failure(rt.poll_failures())
    checkpoint()

    # deferred join of the casualty
    rt.clock.advance(10.0)
    assert rt.poll_reintegration() == [3]
    checkpoint()

    # drain
    rt.control.drain(1)
    checkpoint()

    # undrain
    rt.control.undrain(1)
    checkpoint()

    # scale down
    rt.control.scale_down(6, 7)
    checkpoint()

    # scale up rides the deferred-join path: the commit lands at the join
    rt.control.scale_up(6, 7)
    rt.clock.advance(10.0)
    assert rt.poll_reintegration() == [6, 7]
    checkpoint()

    # straggler re-place (no membership change, still one commit)
    rt.expert_load = np.array([10.0, 1.0, 1.0, 1.0])
    rt.rank_slowdown[2] = 4.0
    for _ in range(12):
        rt.clock.advance(0.05)
        rt.observe_step_latencies(0.05)
        rt.mitigate_stragglers()
    assert 2 in rt.straggler.flagged
    checkpoint()

    assert epochs == sorted(set(epochs))


def test_membership_commit_events_carry_the_epoch():
    cfg, rt = _runtime()
    rt.control.drain(2)
    commits = [e for e in rt.timeline if e.kind == "membership_commit"]
    assert commits[-1].detail["transition"] == "drain"
    assert commits[-1].detail["epoch"] == rt.epoch
    kinds = [e.detail["transition"] for e in commits]
    assert kinds[0] == "bootstrap"


# ---------------------------------------------------------------------------
# Abort semantics: nothing leaks from a failed transaction
# ---------------------------------------------------------------------------

def test_infeasible_drain_aborts_and_leaves_state_untouched():
    """Draining so many ranks that coverage becomes impossible must REJECT
    the plan (unlike a fault, nothing has broken yet) and leave
    table/params/membership byte-identical."""
    cfg, rt = _runtime(world=6, spr=1)     # 6 slots, 4 experts
    snap = _snapshot(rt)
    with pytest.raises(TransitionAborted):
        rt.drain_ranks([0, 1, 2])          # 3 surviving slots < 4 experts
    _assert_untouched(rt, snap)
    aborts = [e for e in rt.timeline if e.kind == "transition_abort"]
    assert aborts and aborts[0].detail["op"] == "drain"
    # and the instance still serves: a feasible drain afterwards commits
    rt.drain_ranks([0])
    assert rt.epoch == snap["epoch"] + 1


def test_commit_validation_failure_aborts_and_leaves_state_untouched():
    """A transaction whose staged state flunks the validity check (here: an
    activated rank the detector knows is dead) must abort before publish."""
    cfg, rt = _runtime()
    rt.detector.mark_unreachable(5)
    rt.clock.advance(1.5)
    rt.handle_failure(rt.poll_failures())          # rank 5 now inactive
    snap = _snapshot(rt)
    txn = rt.begin("join")
    txn.activate([5])                              # never marked reachable!
    txn.plan()
    rep = txn.validate()
    assert not rep.valid                           # dry-run agrees
    with pytest.raises(TransitionAborted):
        txn.commit()
    _assert_untouched(rt, snap)
    assert txn.state == "aborted"


def test_coverage_loss_still_publishes_the_deaths():
    """A fault whose recovery aborts on coverage loss must not leave the
    published peer set claiming the dead ranks are active: the deaths are
    facts, recorded by a degraded commit even though the (stopped)
    instance is formally invalid."""
    from repro.core.failure import CoverageLossError
    cfg, rt = _runtime(world=6, spr=1)     # 6 slots, 4 experts
    for r in range(1, 6):
        rt.detector.mark_unreachable(r)    # 1 surviving slot < 4 experts
    rt.clock.advance(1.5)
    epoch0 = rt.epoch
    with pytest.raises(CoverageLossError):
        rt.handle_failure(rt.poll_failures())
    assert not rt.table.entries[1].active          # deaths published
    assert rt.active_fraction() == pytest.approx(1 / 6)
    assert _dev_version(rt) == rt.epoch == epoch0 + 1
    commits = [e for e in rt.timeline if e.kind == "membership_commit"]
    assert commits[-1].detail.get("degraded") is True
    assert any(e.kind == "coverage_loss" for e in rt.timeline)


def test_aborted_undrain_via_pump_still_leaves_telemetry():
    """An abort raised by a handler that did not record it (anything but a
    drain) must still surface as a transition_abort event from the pump."""
    from repro.core.transitions import TransitionAborted

    class ExplodingPolicy(ElasticPolicy):
        def on_undrain(self, rt, ranks):
            raise TransitionAborted("synthetic", reason="synthetic")

    cfg, rt = _runtime()
    rt.control.drain(2)
    rt.set_policy(ExplodingPolicy())
    handled, mode = rt.control.undrain(2)
    assert handled == [2] and mode == "aborted"
    aborts = [e for e in rt.timeline if e.kind == "transition_abort"]
    assert aborts and aborts[-1].detail["op"] == "undrain"


def test_engine_rejects_conflicting_policy_args():
    cfg, rt = _runtime()
    from repro.core.transitions import FullRestartCostModel
    with pytest.raises(ValueError):
        ServingEngine(rt, max_batch=2, max_len=16, fixed_membership=True,
                      policy=ElasticPolicy())
    with pytest.raises(ValueError):
        ServingEngine(rt, max_batch=2, max_len=16,
                      restart_model=FullRestartCostModel(),
                      policy=FullRestartPolicy())


def test_transaction_refuses_use_after_commit_or_abort():
    cfg, rt = _runtime()
    txn = rt.begin("drain")
    txn.deactivate([1], drained=True)
    txn.plan(source_active=rt.table.active_mask)
    txn.commit()
    with pytest.raises(RuntimeError):
        txn.commit()
    with pytest.raises(RuntimeError):
        txn.deactivate([2])


def test_property_random_drain_sets_commit_or_roll_back():
    """Property test: for ANY subset of ranks, a drain either commits (epoch
    +1, validity holds, exactly the requested ranks inactive) or aborts
    with the state untouched — never a half-applied transition."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    cfg = get_config("mixtral-8x22b").reduced()

    @settings(max_examples=20, deadline=None)
    @given(ranks=st.sets(st.integers(min_value=0, max_value=5),
                         min_size=1, max_size=5))
    def prop(ranks):
        table = make_initial_membership(6, cfg.moe.num_experts, 1)
        params = init_params(cfg, jax.random.key(0), jnp.float32,
                             table.slot_to_expert, table.num_slots)
        rt = ElasticEPRuntime(cfg, params, table)
        snap = _snapshot(rt)
        feasible = 6 - len(ranks) >= cfg.moe.num_experts
        if feasible:
            rt.drain_ranks(sorted(ranks))
            assert rt.epoch == snap["epoch"] + 1
            assert _dev_version(rt) == rt.epoch
            from repro.core.validity import check
            rep = check(rt.table, rt.membership,
                        reachable=rt.detector.known_reachable())
            assert rep.valid, rep.violations
            assert set(np.nonzero(~rt.table.active_mask)[0]) == ranks
        else:
            with pytest.raises(TransitionAborted):
                rt.drain_ranks(sorted(ranks))
            _assert_untouched(rt, snap)

    prop()


# ---------------------------------------------------------------------------
# ControlPlane + planned-transition mechanics
# ---------------------------------------------------------------------------

def test_drain_uses_departing_rank_as_tier2_source():
    """Unlike a fault casualty, a draining rank is alive through the
    transfer window: its uniquely-hosted experts move GPU-to-GPU (Tier 2),
    never via Tier-3 DRAM reload."""
    cfg, rt = _runtime(world=8, spr=1)     # 8 slots, 4 experts, R=2
    handled, mode = rt.control.drain(0)
    assert handled == [0] and mode == "elastic"
    ev = [e for e in rt.timeline if e.kind == "drain"][-1]
    assert ev.detail["mix"]["dram_reload"] == 0
    assert ev.detail["tier3_bytes"] == 0
    assert ev.detail["mix"]["gpu_relocation"] >= 1
    assert ev.detail["tier2_bytes"] > 0
    # no detect window: the planned pause is well under a fault recovery
    assert ev.detail["pause_s"] < rt.cost_model.detect_s + \
        rt.cost_model.drain_s + rt.cost_model.coordinate_s


def test_drained_rank_is_not_relaunched_and_keeps_heartbeating():
    cfg, rt = _runtime()
    rt.control.drain(2)
    assert rt.table.entries[2].drained
    assert not rt.controller.is_recovering(2)      # no relaunch scheduled
    # a failure elsewhere must not relaunch the drained rank either
    rt.detector.mark_unreachable(5)
    rt.clock.advance(1.5)
    rt.handle_failure(rt.poll_failures())
    assert not rt.controller.is_recovering(2)
    assert rt.controller.is_recovering(5)
    # drained ranks heartbeat (alive, idling): the detector never misreads
    # the planned drain as a fault
    for _ in range(40):
        rt.clock.advance(0.1)
        rt.heartbeat()
    assert 2 not in rt.detector.poll()


def test_undrain_of_a_rank_that_died_while_drained_takes_warmup_path():
    cfg, rt = _runtime()
    rt.control.drain(2)
    rt.injector.inject_at(rt.clock.now() + 0.5, [2])
    rt.clock.advance(1.0)
    rt.injector.step()                      # the drained rank's process dies
    assert not rt.detector.reachable[2]
    handled, _ = rt.control.undrain(2)
    assert handled == [2]
    assert rt.controller.is_recovering(2)   # relaunch, not instant rejoin
    assert not rt.table.entries[2].active
    # idempotent re-request must NOT restart the in-flight warmup
    rt.clock.advance(2.0)
    t_state = rt.controller.recovering[2].t_state_entered
    assert rt.control.undrain(2) == ([], None)
    assert rt.controller.recovering[2].t_state_entered == t_state
    rt.clock.advance(10.0)
    assert rt.poll_reintegration() == [2]
    assert rt.table.entries[2].active and not rt.table.entries[2].drained


def test_scale_up_rides_the_deferred_join_path():
    cfg, rt = _runtime()
    rt.control.scale_down(6, 7)
    assert rt.active_fraction() == 0.75
    rt.control.scale_up(6, 7)
    assert rt.controller.is_recovering(6) and rt.controller.is_recovering(7)
    warm = [s for s in rt.obs.spans if s.phase == "warmup"
            and s.meta.get("planned")]
    assert {s.meta["rank"] for s in warm} == {6, 7}
    rt.clock.advance(10.0)
    assert rt.poll_reintegration() == [6, 7]       # ONE batched join patch
    assert rt.active_fraction() == 1.0
    patches = [s for s in rt.obs.spans if s.phase == "table-patch"]
    assert len(patches) == 1


def test_control_plane_filters_ineligible_ranks():
    cfg, rt = _runtime()
    assert rt.control.undrain(3) == ([], None)     # nothing drained
    rt.control.drain(3)
    assert rt.control.drain(3) == ([], None)       # already drained
    assert rt.control.scale_up(1) == ([], None)    # rank 1 is active


def test_full_restart_policy_answers_drain_with_a_restart():
    """The fixed-membership baseline has exactly one move for planned
    maintenance too — rebuild the instance (which is the paper's point)."""
    cfg, rt = _runtime()
    eng = ServingEngine(rt, max_batch=2, max_len=32, fixed_membership=True)
    assert isinstance(rt.policy, FullRestartPolicy)
    assert isinstance(rt.policy, TransitionPolicy)  # protocol conformance
    handled, mode = rt.control.drain(2)
    assert handled == [2] and mode == "restart"
    kinds = [e.kind for e in rt.timeline]
    assert "full_restart_done" in kinds
    assert rt.table.entries[2].active              # membership CANNOT change
    spans = [s.phase for s in rt.obs.spans]
    assert spans.count("full-restart") == 1
    restart = [s for s in rt.obs.spans if s.phase == "full-restart"][0]
    assert restart.duration_s == pytest.approx(348.0)   # baseline parity
    assert eng.compile_count() == 0 or eng.compile_count() == 1


def test_elastic_policy_protocol_conformance():
    assert isinstance(ElasticPolicy(), TransitionPolicy)
    assert ElasticPolicy().mutates_membership
    assert not FullRestartPolicy().mutates_membership


# ---------------------------------------------------------------------------
# Engine requeue semantics for drained slots
# ---------------------------------------------------------------------------

def test_drain_preempts_inflight_requests_without_failing_them():
    cfg, rt = _runtime()
    eng = ServingEngine(rt, max_batch=4, max_len=64)
    for i in range(4):
        eng.sched.submit(Request(rid=i, prompt=[1] * 6, max_new_tokens=24))
    for _ in range(5):
        eng.step()
    assert eng.sched.inflight > 0
    rt.control.request("drain", [2])               # lands at the next step
    eng.step()
    st = eng.sched.stats
    assert st.preempted > 0
    assert st.failed == 0 and st.retried == 0 and st.dropped == 0
    # the preempted work resumes and completes on the shrunken instance
    eng.run(until=rt.clock.now() + 60.0, max_steps=3000)
    assert eng.sched.stats.finished == 4
    assert eng.compile_count() == 1


def test_scheduler_preempt_requeues_front_without_retry_budget():
    from repro.serving.kv_cache import KVCacheManager
    from repro.serving.scheduler import Scheduler
    kv = KVCacheManager(num_slots=2, max_len=32)
    sched = Scheduler(kv, max_retries=0)           # zero retry budget
    for i in range(3):
        sched.submit(Request(rid=i, prompt=[1], max_new_tokens=4))
    sched.admit()
    sched.preempt_inflight()
    assert [r.rid for r in sched.queue] == [0, 1, 2]   # preempted go FIRST
    assert sched.stats.preempted == 2
    assert sched.stats.failed == sched.stats.dropped == 0
    sched.admit()                                  # re-admits despite budget
    assert sched.inflight == 2


# ---------------------------------------------------------------------------
# Structural enforcement: one commit path
# ---------------------------------------------------------------------------

def test_runtime_and_engine_have_no_direct_mutation_call_sites():
    """The acceptance contract, enforced on the source itself: nothing in
    the runtime or the serving engine calls set_placement / to_device /
    the validity checker directly — every mutation is a
    MembershipTransaction commit."""
    import inspect
    import repro.runtime.elastic as elastic
    import repro.serving.engine as engine
    for mod in (elastic, engine):
        src = inspect.getsource(mod)
        assert ".set_placement(" not in src, mod.__name__
        assert ".to_device(" not in src, mod.__name__
        assert "validity_check(" not in src, mod.__name__
        assert ".reactivate(" not in src, mod.__name__
        assert ".deactivate(" not in src or mod is elastic, mod.__name__
    # the runtime's only deactivations are transaction-staged
    src = inspect.getsource(elastic)
    assert "txn.deactivate(" in src
    assert "self.table.deactivate(" not in src


def test_mixed_run_single_compile_and_monotonic_epochs():
    """One run mixing faults, a drain/undrain and a scale down/up: the jit
    cache stays at 1 and every commit strictly bumps the epoch (the
    acceptance scenario for the transactional redesign)."""
    from repro.runtime.scenario_runner import run_scenario
    res = run_scenario("mixed_planned_unplanned")
    assert res.compile_count == 1
    assert res.invariants_ok, res.validity_violations[:3]
    assert res.recoveries >= 1 and res.drains >= 1 and res.scale_ups >= 1
    epochs = [e["detail"]["epoch"] for e in res.timeline
              if e["kind"] == "membership_commit"]
    assert len(epochs) >= 5                       # one per transition kind
    assert epochs == sorted(set(epochs))
    assert res.final_epoch == epochs[-1]
    assert res.final_active_fraction == 1.0


# ---------------------------------------------------------------------------
# Satellite fixes
# ---------------------------------------------------------------------------

def test_set_moe_slot_leaves_targeted_copy_shares_untouched_subtrees():
    cfg, rt = _runtime()
    params = rt.params
    leaves = moe_slot_leaves(cfg, params)
    (first_key, first_leaf), *rest = list(leaves.items())
    new_leaf = first_leaf + 1.0
    out = set_moe_slot_leaves(params, {first_key: new_leaf})
    g, l, w = first_key
    # the swapped leaf landed; the original tree is untouched
    assert out["groups"][g][l]["moe"][w] is new_leaf
    assert params["groups"][g][l]["moe"][w] is first_leaf
    # every OTHER subtree is shared, not copied: same objects
    for (g2, l2, w2), leaf in rest:
        assert out["groups"][g2][l2]["moe"][w2] is leaf
    for key in params:
        if key != "groups":
            assert out[key] is params[key]
    untouched_layers = [(gn, ln) for gn, grp in params["groups"].items()
                        for ln in grp if (gn, ln) != (g, l)]
    for gn, ln in untouched_layers:
        assert out["groups"][gn][ln] is params["groups"][gn][ln]
    # empty patch: identity
    assert set_moe_slot_leaves(params, {}) is params


def test_straggler_mitigation_reports_real_transfer_bytes():
    """The straggler re-place telemetry must carry the actual tier2/tier3
    byte counts (the plan is built with bytes_per_slot now)."""
    cfg, rt = _runtime(world=8, spr=2)
    rt.expert_load = np.array([10.0, 1.0, 1.0, 1.0])
    rt.rank_slowdown[3] = 3.0
    for _ in range(12):
        rt.clock.advance(0.05)
        rt.observe_step_latencies(0.05)
        rt.mitigate_stragglers()
    evs = [e for e in rt.timeline if e.kind == "straggler_mitigation"]
    assert evs and 3 in evs[0].detail["flagged"]
    assert evs[0].detail["tier2_bytes"] > 0       # was always 0 before
    assert evs[0].detail["epoch"] == rt.epoch


def test_mid_transfer_recovery_events_carry_incident_tags():
    """Every event emitted inside the repair-transfer window (cascade
    restarts, tier escalations) is stamped with its incident."""
    scn = Scenario(name="tmp_esc2", description="", schedule="@0 fail 0",
                   world=8, slots_per_rank=1)
    rt = build_scenario_runtime(scn)
    rt.cost_model = RecoveryCostModel(ici_gbps=1e-9, host_gbps=1e-9)
    rt.detector.mark_unreachable(0)
    rt.clock.advance(1.5)
    failed = rt.poll_failures()
    rt.injector.inject_at(rt.clock.now() + 2.4, [4])
    rt.handle_failure(failed)
    tagged = [e for e in rt.obs.events
              if e.kind in ("recovery_restart", "transfer_escalation",
                            "failure", "recovery_done", "coverage_loss")]
    assert tagged
    assert all(e.incident >= 0 for e in tagged), \
        [(e.kind, e.incident) for e in tagged]


# ---------------------------------------------------------------------------
# Popularity rebalance: a rank-less planned transition (ISSUE 8)
# ---------------------------------------------------------------------------

def test_rebalance_commits_one_epoch_and_follows_load():
    """control.rebalance() is one MembershipTransaction commit over the
    whole active set: epoch +1, device version mirrors it, and the new
    placement over-replicates the tracked-hot experts."""
    cfg, rt = _runtime()
    epoch0 = rt.epoch
    rt.expert_load = np.array([0.4, 0.4, 0.1, 0.1])
    handled, mode = rt.control.rebalance()
    assert mode == "elastic"
    assert sorted(handled) == list(range(8))       # rank-less: everyone
    assert rt.epoch == epoch0 + 1
    assert _dev_version(rt) == rt.epoch
    counts = rt.expert_replica_counts()
    assert counts[0] > counts[2] and counts[1] > counts[3]
    commits = [e for e in rt.timeline if e.kind == "membership_commit"]
    assert commits[-1].detail["transition"] == "rebalance"
    reb = [e for e in rt.timeline if e.kind == "rebalance"]
    assert reb and reb[-1].detail["epoch"] == rt.epoch


def test_rebalance_txn_abort_leaves_state_byte_identical():
    """Planning a rebalance and aborting it publishes NOTHING: table,
    params and device membership stay byte-identical."""
    cfg, rt = _runtime()
    rt.expert_load = np.array([0.7, 0.1, 0.1, 0.1])
    snap = _snapshot(rt)
    txn = rt.begin("rebalance")
    plan = txn.plan()
    assert plan is not None and plan.tier2          # it WOULD move weights
    txn.abort()
    _assert_untouched(rt, snap)
    assert txn.state == "aborted"
    with pytest.raises(RuntimeError):
        txn.commit()


def test_rebalance_policy_abort_via_pump_records_telemetry():
    """An abort raised inside the rebalance handler surfaces as a
    transition_abort event and the control plane reports 'aborted'."""
    from repro.core.transitions import TransitionAborted

    class ExplodingPolicy(ElasticPolicy):
        def on_rebalance(self, rt, ranks):
            raise TransitionAborted("synthetic", reason="synthetic")

    cfg, rt = _runtime()
    snap = _snapshot(rt)
    rt.set_policy(ExplodingPolicy())
    handled, mode = rt.control.rebalance()
    assert mode == "aborted"
    _assert_untouched(rt, snap)
    aborts = [e for e in rt.timeline if e.kind == "transition_abort"]
    assert aborts and aborts[-1].detail["op"] == "rebalance"


def test_fault_landing_mid_rebalance_composes():
    """A rank dies inside the rebalance's coordinate window: the rebalance
    commit lands first, the banked fault is detected at the next poll, and
    the follow-up recovery is its own strictly-later commit — two
    transitions, two epochs, coverage intact throughout."""
    cfg, rt = _runtime()
    rt.expert_load = np.array([0.4, 0.4, 0.1, 0.1])
    epoch0 = rt.epoch
    rt.injector.inject_at(rt.clock.now() + 0.3, [5])   # inside coordinate_s
    handled, mode = rt.control.rebalance()
    assert mode == "elastic"
    assert rt.epoch == epoch0 + 1
    rt.clock.advance(1.5)                              # heartbeat timeout
    fails = rt.poll_failures()
    assert fails == [5]
    rt.handle_failure(fails)
    assert rt.epoch == epoch0 + 2
    assert _dev_version(rt) == rt.epoch
    # coverage survived both transitions; hot experts still over-replicated
    counts = rt.expert_replica_counts()
    assert all(c >= 1 for c in counts.values())
    assert counts[0] > counts[3]
    epochs = [e.detail["epoch"] for e in rt.timeline
              if e.kind == "membership_commit"]
    assert epochs == sorted(set(epochs))


def test_rebalance_keeps_single_compile_with_engine():
    """Serving across a live rebalance never recompiles the serve step:
    the placement change is a table patch, not a new graph shape."""
    from repro.core.scenarios import get_scenario
    from repro.serving.api import ServingFrontend
    scn = get_scenario("static_hot_expert")
    rt = build_scenario_runtime(scn)
    eng = ServingEngine(rt, max_batch=4, max_len=32)
    fe = ServingFrontend(eng)
    rt.set_router_skew(np.array([0.4, 0.4, 0.1, 0.1]))
    for _ in range(40):
        while len(eng.sched.queue) < 4:
            fe.submit([1, 2, 3], max_new=8)
        fe.step()
    resp = fe.admin.execute({"cmd": "rebalance"})
    assert "error" not in resp, resp
    for _ in range(40):
        while len(eng.sched.queue) < 4:
            fe.submit([1, 2, 3], max_new=8)
        fe.step()
    assert eng.compile_count() == 1
    counts = rt.expert_replica_counts()
    assert counts[0] > counts[2]                   # EMA drove the re-place
    assert rt.load_imbalance() < 1.2


def test_admin_rebalance_rejects_ranks():
    from repro.core.scenarios import get_scenario
    from repro.serving.api import ServingFrontend
    rt = build_scenario_runtime(get_scenario("static_hot_expert"))
    eng = ServingEngine(rt, max_batch=2, max_len=16)
    fe = ServingFrontend(eng)
    resp = fe.admin.execute({"cmd": "rebalance", "ranks": [1]})
    assert "error" in resp and "no 'ranks'" in resp["error"]


def test_rebalance_goes_through_the_transaction_path():
    """Structural: the runtime's rebalance is a MembershipTransaction like
    every other mutation — no side-channel placement writes."""
    import inspect
    import repro.runtime.elastic as elastic
    src = inspect.getsource(elastic)
    assert 'self.begin("rebalance"' in src
