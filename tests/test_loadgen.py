"""Client-storm load subsystem (repro.serving.loadgen) + SLO scheduling:

  * workload synthesis — byte-identical sessions from the same seed,
    different sessions from a different seed, lengths/ids within spec
    bounds, arrivals sorted and inside the window, tenant mix honored;
  * storm determinism — two fresh frontends driven by the same seeded
    workload produce IDENTICAL scorecards (the reproducibility claim the
    --seed flag makes);
  * EDF vs FIFO — on the same overloaded workload, deadline-aware queue
    ordering strictly beats FIFO on deadline-miss count (the gated SLO
    claim behind the `slo` benchmark cells);
  * tenant quotas — a storm from one tenant cannot occupy more than its
    quota of live streams; rejections are terminal REJECTED events and
    show up in the per-tenant metrics buckets;
  * admission depth — a pending interrupting transition (drain / fault
    detection sitting in the control queue) makes in-flight work count
    toward queue depth, so admission cannot overshoot the cap in the
    window where everything is about to requeue;
  * ci_compare — the `load` extractor round-trips the benchmark artifact
    and hard-fails nonzero violation counts and EDF-worse-than-FIFO.
"""
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import make_initial_membership
from repro.core.reintegration import WarmupCostModel
from repro.models import init_params
from repro.runtime.elastic import ElasticEPRuntime
from repro.serving.api import ServingFrontend
from repro.serving.engine import ServingEngine
from repro.serving.loadgen import (
    TenantSpec,
    WorkloadSpec,
    build_sessions,
    run_storm,
    summarize,
)


def _frontend(seed=0, max_batch=8, max_len=64, queue_policy="fifo", **fe_kw):
    cfg = get_config("mixtral-8x22b").reduced()
    table = make_initial_membership(8, cfg.moe.num_experts, 1)
    params = init_params(cfg, jax.random.key(seed), jnp.float32,
                         table.slot_to_expert, table.num_slots)
    rt = ElasticEPRuntime(cfg, params, table,
                          warmup_model=WarmupCostModel(1, 1, 2, 1))
    eng = ServingEngine(rt, max_batch=max_batch, max_len=max_len,
                        queue_policy=queue_policy)
    return rt, ServingFrontend(eng, **fe_kw)


# ---------------------------------------------------------------------------
# Workload synthesis
# ---------------------------------------------------------------------------

def test_build_sessions_is_deterministic_per_seed():
    spec = WorkloadSpec(rate_rps=50.0, duration_s=2.0,
                        tenants=(TenantSpec("a", 1.0, deadline_s=3.0),
                                 TenantSpec("b", 2.0)))
    one = build_sessions(spec, seed=7)
    two = build_sessions(spec, seed=7)
    assert one == two                      # dataclass equality, every field
    other = build_sessions(spec, seed=8)
    assert one != other


def test_sessions_respect_spec_bounds():
    spec = WorkloadSpec(rate_rps=200.0, duration_s=1.0, prompt_mean=6,
                        prompt_max=16, out_mean=5, out_max=10, vocab=100,
                        tenants=(TenantSpec("a", 1.0, deadline_s=2.5),
                                 TenantSpec("b", 3.0)))
    sessions = build_sessions(spec, seed=0)
    assert len(sessions) > 50
    arrivals = [s.t_arrival for s in sessions]
    assert arrivals == sorted(arrivals)
    assert all(0 < t <= spec.duration_s for t in arrivals)
    for s in sessions:
        assert 1 <= len(s.prompt) <= spec.prompt_max
        assert 1 <= s.max_new <= spec.out_max
        assert all(1 <= tok < spec.vocab for tok in s.prompt)
        assert s.tenant in ("a", "b")
        assert s.deadline_s == (2.5 if s.tenant == "a" else None)
    # the 3:1 weighted mix shows in the draw (loose: just the ordering)
    by_tenant = {"a": 0, "b": 0}
    for s in sessions:
        by_tenant[s.tenant] += 1
    assert by_tenant["b"] > by_tenant["a"]


def test_n_max_caps_generation():
    spec = WorkloadSpec(rate_rps=1000.0, duration_s=10.0, n_max=25)
    assert len(build_sessions(spec, seed=1)) == 25


# ---------------------------------------------------------------------------
# Storm determinism
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_run_storm_is_deterministic():
    spec = WorkloadSpec(rate_rps=30.0, duration_s=1.5, prompt_mean=5,
                        prompt_max=12, out_mean=4, out_max=8)
    sessions = build_sessions(spec, seed=5)
    cards = []
    for _ in range(2):
        _, fe = _frontend(seed=5)
        cards.append(summarize(run_storm(fe, sessions)))
    assert cards[0] == cards[1]
    assert cards[0]["transport_errors"] == 0
    assert cards[0]["stream_violations"] == 0


# ---------------------------------------------------------------------------
# EDF vs FIFO: the gated SLO claim
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_edf_beats_fifo_on_deadline_misses():
    """Same overloaded two-tenant workload, same engines, only the queue
    policy differs: EDF must strictly reduce deadline misses. This is the
    in-repo version of the benchmark's slo[fifo]/slo[edf] gate."""
    duration = 4.0
    spec = WorkloadSpec(
        rate_rps=24.0, duration_s=duration, prompt_mean=8, prompt_max=20,
        out_mean=8, out_max=16,
        tenants=(TenantSpec("paid", 1.0, deadline_s=duration),
                 TenantSpec("batch", 2.0)))
    sessions = build_sessions(spec, seed=2)
    misses = {}
    for policy in ("fifo", "edf"):
        _, fe = _frontend(seed=2, queue_policy=policy,
                          tenant_quotas=spec.quotas())
        card = summarize(run_storm(fe, sessions))
        assert card["stream_violations"] == 0
        misses[policy] = card["deadline_misses"]
    assert misses["fifo"] > 0, \
        "workload not overloaded enough to exercise the deadline path"
    assert misses["edf"] < misses["fifo"], misses


def test_edf_orders_queue_by_deadline():
    """Unit-level: with requests already queued, EDF admits the tightest
    deadline first while FIFO admits submit order."""
    for policy in ("fifo", "edf"):
        _, fe = _frontend(max_batch=1, queue_policy=policy)
        handles = [fe.submit([3, 1, 4], max_new=2, deadline=d)
                   for d in (50.0, 40.0, 30.0)]
        # one slot: rid 0 runs immediately either way; 1 and 2 queue
        fe.run(max_steps=2_000)
        first_tok = {h.rid: min(e.t for e in h.events if e.kind == "TOKEN")
                     for h in handles}
        assert all(h.outcome == "FINISHED" for h in handles)
        if policy == "edf":
            # rid 2 (deadline 30) streams before rid 1 (deadline 40)
            assert first_tok[2] < first_tok[1]
        else:
            assert first_tok[1] < first_tok[2]


def test_scheduler_rejects_unknown_queue_policy():
    with pytest.raises(ValueError, match="queue_policy"):
        _frontend(queue_policy="lifo")


# ---------------------------------------------------------------------------
# Tenant quotas + per-tenant metrics
# ---------------------------------------------------------------------------

def test_tenant_quota_rejects_excess_live_streams():
    _, fe = _frontend(tenant_quotas={"noisy": 2})
    noisy = [fe.submit([1, 2, 3], max_new=4, tenant="noisy")
             for _ in range(5)]
    quiet = fe.submit([1, 2, 3], max_new=4, tenant="quiet")
    # the first two live noisy streams fill the quota; 3..5 are refused
    assert [h.outcome for h in noisy[:2]] == [None, None]
    for h in noisy[2:]:
        assert h.outcome == "REJECTED"
        assert h.events[-1].detail["reason"] == "tenant_quota"
    assert quiet.outcome is None           # other tenants unaffected
    fe.run(max_steps=2_000)
    m = fe.metrics()
    assert m["rejected_admission"] == 3
    noisy_bucket = m["tenants"]["noisy"]
    assert noisy_bucket["submitted"] == 5
    assert noisy_bucket["admitted"] == 2
    assert noisy_bucket["rejected"] == 3
    assert noisy_bucket["finished"] == 2
    assert noisy_bucket["delivered_tokens"] == 8   # 2 streams x max_new=4
    assert m["tenants"]["quiet"]["finished"] == 1
    # quota frees as streams finish: the tenant can submit again
    again = fe.submit([1, 2, 3], max_new=2, tenant="noisy")
    assert again.outcome is None


def test_storm_under_quota_keeps_other_tenant_flowing():
    spec = WorkloadSpec(rate_rps=40.0, duration_s=1.5, prompt_mean=5,
                        prompt_max=10, out_mean=4, out_max=8,
                        tenants=(TenantSpec("noisy", 3.0, quota=2),
                                 TenantSpec("quiet", 1.0)))
    sessions = build_sessions(spec, seed=4)
    _, fe = _frontend(tenant_quotas=spec.quotas())
    card = summarize(run_storm(fe, sessions))
    assert card["tenants"]["noisy"]["rejected"] > 0
    assert card["tenants"]["quiet"]["rejected"] == 0
    assert card["tenants"]["quiet"]["finished"] \
        == card["tenants"]["quiet"]["sessions"]
    assert card["stream_violations"] == 0


# ---------------------------------------------------------------------------
# Admission depth: in-flight work counts while a transition is pending
# ---------------------------------------------------------------------------

def test_pending_transition_counts_inflight_toward_depth():
    rt, fe = _frontend(max_batch=2, max_queue_depth=4)
    for _ in range(2):
        fe.submit([3, 1, 4], max_new=8)
    fe.step()
    assert fe.engine.sched.inflight == 2 and not fe.engine.sched.queue
    # a drain is REQUESTED but not yet committed: it sits in the control
    # queue until the next step boundary, where both in-flight requests
    # will be pushed back onto the queue
    rt.control.request("drain", [5])
    assert rt.control_queue
    handles = [fe.submit([3, 1, 4], max_new=4) for _ in range(4)]
    outcomes = [h.outcome for h in handles]
    # effective depth starts at 2 (the in-flight pair): only 2 of the 4
    # fit under max_queue_depth=4
    assert outcomes == [None, None, "REJECTED", "REJECTED"]
    for h in handles[2:]:
        assert h.events[-1].detail["reason"] == "queue_full"
    # after the drain commits, the queue holds exactly the cap — no
    # overshoot in the requeue window
    fe.step()
    assert len(fe.engine.sched.queue) + fe.engine.sched.inflight <= 4
    fe.run(max_steps=5_000)
    assert fe.stream_violations() == []


def test_no_pending_transition_means_plain_queue_depth():
    _, fe = _frontend(max_batch=2, max_queue_depth=4)
    for _ in range(2):
        fe.submit([3, 1, 4], max_new=8)
    fe.step()
    assert fe.engine.sched.inflight == 2
    # no pending interrupt: in-flight work is NOT about to requeue, so
    # all four fit in the queue-depth budget
    handles = [fe.submit([3, 1, 4], max_new=4) for _ in range(4)]
    assert [h.outcome for h in handles] == [None] * 4


# ---------------------------------------------------------------------------
# ci_compare: the `load` trajectory extractor
# ---------------------------------------------------------------------------

def _load_doc(*, violations=0, elastic_errors=0, fifo_miss=0.25,
              edf_miss=0.05):
    def row(rate, policy, errors):
        return {"cell": "load", "rate_rps": rate, "policy": policy,
                "goodput_tok_s": 20.0 * rate / 8, "ttft_p50_s": 0.2,
                "ttft_p99_s": 0.9, "stall_p50_s": 0.05, "stall_p99_s": 0.4,
                "stream_violations": violations, "transport_errors": 0,
                "error_events": errors}
    def slo(sched, miss):
        return {"cell": "slo", "sched": sched, "goodput_tok_s": 30.0,
                "ttft_p50_s": 0.3, "ttft_p99_s": 1.2, "stall_p50_s": 0.05,
                "stall_p99_s": 0.5, "deadline_miss_rate": miss,
                "stream_violations": 0, "transport_errors": 0}
    return {"load": [row(8, "elastic", elastic_errors),
                     row(8, "full_restart", 7),
                     slo("fifo", fifo_miss), slo("edf", edf_miss)]}


def test_ci_compare_load_roundtrip():
    from benchmarks import ci_compare
    cur = ci_compare._load_metrics(_load_doc())
    assert "load/r8[elastic]/goodput_tok_s" in cur
    assert cur["load/r8[elastic]/error_events"] == (0.0, "zero")
    # full_restart errors are EXPECTED: no hard-zero gate on that row
    assert "load/r8[full_restart]/error_events" not in cur
    assert cur["slo/edf_excess_miss_rate"] == (0.0, "zero")
    assert ci_compare.compare(cur, cur, tolerance=0.15) == []


def test_ci_compare_load_gates_hard_failures():
    from benchmarks import ci_compare
    good = ci_compare._load_metrics(_load_doc())
    # any stream-contract violation fails regardless of baseline
    bad = ci_compare._load_metrics(_load_doc(violations=2))
    assert any("stream_violations" in b
               for b in ci_compare.compare(good, bad, tolerance=0.15))
    # an elastic row with client-visible errors fails
    bad = ci_compare._load_metrics(_load_doc(elastic_errors=1))
    assert any("error_events" in b
               for b in ci_compare.compare(good, bad, tolerance=0.15))
    # EDF missing MORE deadlines than FIFO fails as a relation, even if
    # each absolute rate individually stayed within tolerance of baseline
    bad = ci_compare._load_metrics(_load_doc(fifo_miss=0.05, edf_miss=0.06))
    assert any("edf_excess_miss_rate" in b
               for b in ci_compare.compare(bad, bad, tolerance=0.15))
