"""Popularity-aware placement under router skew: hypothesis property suite
plus the repair-ordering / placement_overlap edge cases.

The properties pin down what `eplb_place` promises when fed a tracked load
vector:
  * full expert coverage for ANY load and failure pattern (or an explicit
    infeasibility report),
  * replica counts monotone non-decreasing in tracked load,
  * the hot expert's replicas spread across distinct ranks AND hosts
    whenever the fleet makes that feasible (anti-affinity),
  * deterministic, byte-identical output under tied loads and under load
    rescaling (the planner is a pure function of the normalized load).
"""
import numpy as np
import pytest

try:        # unlike the sibling suites, the unit tests below run even
    #         without the dev extra — only the properties need hypothesis
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

    def given(*a, **k):                # no-op decorators so the module
        def deco(f):                   # still imports cleanly
            return f
        return deco

    settings = given

    class _StrategyStub:               # strategy expressions evaluate at
        def __getattr__(self, name):   # decoration time; swallow them
            return lambda *a, **k: None

    st = _StrategyStub()

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="dev extra not installed: pip install -e .[dev]")

from repro.core import eplb_place, make_initial_membership, plan_repair
from repro.core.backup import BackupStore
from repro.core.placement import placement_overlap
from repro.core.topology import FaultDomainTree


def _loads(draw, n):
    vals = draw(st.lists(st.integers(1, 50), min_size=n, max_size=n))
    return np.asarray(vals, np.float64)


# ---------------------------------------------------------------------------
# Properties
# ---------------------------------------------------------------------------


@needs_hypothesis
@settings(max_examples=60, deadline=None)
@given(world=st.integers(2, 10), spr=st.integers(1, 3),
       e_log=st.integers(2, 16), data=st.data())
def test_property_skewed_coverage_any_failure(world, spr, e_log, data):
    """For ANY load vector and ANY failure pattern: every expert keeps a
    replica on an active rank, or EPLB reports infeasibility — popularity
    weighting never trades coverage away."""
    E = min(e_log, world * spr)
    n_fail = data.draw(st.integers(0, world - 1))
    failed = data.draw(st.permutations(range(world)))[:n_fail]
    active = np.ones(world, bool)
    active[list(failed)] = False
    load = _loads(data.draw, E)
    res = eplb_place(E, world, spr, active, load=load)
    if active.sum() * spr < E:
        assert res.infeasible
        return
    assert not res.infeasible
    for e in range(E):
        slots = res.replicas[e]
        assert len(slots) >= 1
        assert all(active[s // spr] for s in slots)


@needs_hypothesis
@settings(max_examples=60, deadline=None)
@given(world=st.integers(2, 10), spr=st.integers(1, 3), data=st.data())
def test_property_replicas_monotone_in_load(world, spr, data):
    """A strictly hotter expert never gets FEWER replicas than a colder
    one (replica counts are monotone in tracked load)."""
    E = min(data.draw(st.integers(2, 12)), world * spr)
    load = _loads(data.draw, E)
    res = eplb_place(E, world, spr, np.ones(world, bool), load=load)
    assert not res.infeasible
    counts = np.array([len(res.replicas[e]) for e in range(E)])
    norm = load / load.sum()
    for i in range(E):
        for j in range(E):
            if norm[i] > norm[j] + 1e-12:
                assert counts[i] >= counts[j], (
                    f"load {norm[i]:.3f}>{norm[j]:.3f} but replicas "
                    f"{counts[i]}<{counts[j]}")


@needs_hypothesis
@settings(max_examples=60, deadline=None)
@given(world=st.integers(2, 12), spr=st.integers(1, 3), data=st.data())
def test_property_hot_expert_anti_affinity(world, spr, data):
    """The hottest expert's replicas land on distinct ranks — and distinct
    HOSTS — whenever the fleet has enough of them (it places first into an
    empty fleet, so anti-affinity is always feasible for it)."""
    E = min(data.draw(st.integers(2, 12)), world * spr)
    load = _loads(data.draw, E)
    topo = FaultDomainTree(world, ranks_per_host=2, hosts_per_switch=2)
    res = eplb_place(E, world, spr, np.ones(world, bool), load=load,
                     topology=topo)
    assert not res.infeasible
    hot = int(np.argmax(load))  # ties resolve to the lowest index, same
    #                             tie-break the stable planner sort uses
    slots = res.replicas[hot]
    ranks = {s // spr for s in slots}
    assert len(ranks) == min(len(slots), world)
    hosts = {topo.host_of(r) for r in ranks}
    assert len(hosts) == min(len(slots), topo.num_hosts)


@needs_hypothesis
@settings(max_examples=60, deadline=None)
@given(world=st.integers(2, 10), spr=st.integers(1, 3), data=st.data())
def test_property_tied_loads_deterministic(world, spr, data):
    """Byte-identical output on repeated calls — including under tied
    loads, where an unstable sort would let float noise pick the order —
    and invariant under rescaling (only the normalized load matters)."""
    E = min(data.draw(st.integers(2, 12)), world * spr)
    # force heavy ties: loads drawn from a tiny value set
    vals = data.draw(st.lists(st.sampled_from([1.0, 2.0, 5.0]),
                              min_size=E, max_size=E))
    load = np.asarray(vals, np.float64)
    a = eplb_place(E, world, spr, np.ones(world, bool), load=load)
    b = eplb_place(E, world, spr, np.ones(world, bool), load=load.copy())
    c = eplb_place(E, world, spr, np.ones(world, bool), load=load * 37.5)
    assert np.array_equal(a.slot_to_expert, b.slot_to_expert)
    assert np.array_equal(a.slot_to_expert, c.slot_to_expert)


# ---------------------------------------------------------------------------
# Unit: skewed placement shapes
# ---------------------------------------------------------------------------


def test_all_load_on_one_expert_caps_and_covers():
    """Degenerate skew: one expert takes ~everything. It gets as many
    replicas as the cap allows; every other expert still keeps coverage."""
    E, world, spr = 4, 8, 2
    load = np.full(E, 1e-9)
    load[2] = 1.0
    res = eplb_place(E, world, spr, np.ones(world, bool), load=load,
                     max_replicas=6)
    assert not res.infeasible
    counts = {e: len(s) for e, s in res.replicas.items()}
    assert counts[2] == 6                      # hot expert hits the cap
    assert all(c >= 1 for c in counts.values())


def test_uniform_load_matches_none():
    """An explicitly uniform load vector is the same as no load at all."""
    a = eplb_place(4, 8, 2, np.ones(8, bool))
    b = eplb_place(4, 8, 2, np.ones(8, bool), load=np.ones(4))
    assert np.array_equal(a.slot_to_expert, b.slot_to_expert)


def test_reuse_never_pins_expert_twice_on_one_rank():
    """A degraded interim placement that doubled an expert up on one rank
    must not survive the next re-place via Tier-1 pinning when the fleet
    has room to spread."""
    E, world, spr = 4, 4, 2
    prev = np.array([0, 0,   # rank 0 holds expert 0 twice (degraded relic)
                     1, 2,
                     3, 0,
                     1, 2], np.int32)
    res = eplb_place(E, world, spr, np.ones(world, bool),
                     load=np.ones(E), prev_slot_to_expert=prev)
    assert not res.infeasible
    # every expert gets 2 replicas here; a clean spread (one per rank) is
    # feasible, so the relic double must not be pinned back in
    for e, slots in res.replicas.items():
        ranks = [s // spr for s in slots]
        assert len(set(ranks)) == len(ranks), (
            f"expert {e} doubled on a rank: slots {slots}")


# ---------------------------------------------------------------------------
# Unit: placement_overlap edge cases
# ---------------------------------------------------------------------------


def test_overlap_empty_inputs():
    assert placement_overlap(np.array([], np.int32),
                             np.array([], np.int32)) == 0.0


def test_overlap_shape_mismatch_raises():
    with pytest.raises(ValueError, match="shape mismatch"):
        placement_overlap(np.zeros(4, np.int32), np.zeros(6, np.int32))


def test_overlap_all_inactive_slots():
    a = np.full(8, -1, np.int32)
    assert placement_overlap(a, a) == 0.0


def test_overlap_accepts_lists():
    assert placement_overlap([0, 1, 2, 3], [0, 1, 9, 3]) == 0.75


# ---------------------------------------------------------------------------
# Unit: repair ordering under load (hot coverage first on the wire)
# ---------------------------------------------------------------------------


def _expert_of(plan_dst, new_map):
    return int(new_map[plan_dst])


def test_repair_hot_total_loss_transfers_first():
    """A fault kills EVERY replica of the hottest expert: restoring its
    coverage must be the FIRST Tier-2 transfer on the wire, ahead of any
    rebalancing copies of colder experts."""
    spr = 2
    old = np.array([0, 0,      # rank 0: both replicas of hot expert 0
                    1, 2,      # rank 1
                    3, 1,      # rank 2
                    2, 3],     # rank 3
                   np.int32)
    active = np.array([False, True, True, True])
    # survivors re-place: expert 0 must come back from... nowhere live —
    # unless a backup exists. Make expert 0 live on rank 3 instead so the
    # repair is a Tier-2 relocation with a live source.
    old = np.array([0, 1,      # rank 0 dies (held hot 0 + a copy of 1)
                    1, 2,
                    3, 1,
                    2, 0],     # last live replica of hot expert 0
                   np.int32)
    new = np.array([-1, -1,
                    1, 2,
                    3, 0,      # slot 5 re-covers hot expert 0 (Tier-2)
                    2, 1],     # slot 7 re-covers expert 1 (also Tier-2)
                   np.int32)
    load = np.array([100.0, 1.0, 1.0, 1.0])
    plan = plan_repair(old, new, active, spr, load=load)
    assert plan.tier2, "expected GPU relocations"
    first_dst, _ = plan.tier2[0]
    assert _expert_of(first_dst, new) == 0, (
        "hot expert's coverage-restoring copy must be first on the wire")


def test_repair_coverage_before_rebalance_hot_first():
    """Ordering inside the transfer list: coverage-restoring transfers
    (expert has NO Tier-1 slot) precede rebalancing top-ups, and inside
    each class hotter experts go first."""
    spr = 1
    old = np.array([0, 1, 2, 3, 1, 0, 3], np.int32)
    active = np.array([False, True, True, True, True, True, True])
    new = np.array([-1,
                    1,        # Tier-1 (unchanged)
                    1,        # slot 2: rebalance TOP-UP of hot expert 1
                    3,        # Tier-1 (unchanged)
                    1,        # Tier-1 (unchanged)
                    0,        # Tier-1 (unchanged)
                    2],       # slot 6: coverage restore — expert 2's only
                              # new-map replica (its Tier-1 slot 2 was
                              # reassigned to the hot expert)
                   np.int32)
    load = np.array([5.0, 50.0, 2.0, 1.0])
    plan = plan_repair(old, new, active, spr, load=load)
    moved = [_expert_of(d, new) for d, _ in plan.tier2]
    # expert 2 has NO Tier-1 slot left -> coverage class, goes first even
    # though expert 1 is 25x hotter (1's copy is a mere top-up)
    assert moved == [2, 1]


def test_repair_order_deterministic_without_load():
    """load=None keeps the legacy deterministic order: coverage class
    first, then destination slot."""
    spr = 1
    old = np.array([0, 1, 2, 3, 1, 0, 3], np.int32)
    active = np.array([False, True, True, True, True, True, True])
    new = np.array([-1, 1, 1, 3, 1, 0, 2], np.int32)
    a = plan_repair(old, new, active, spr)
    b = plan_repair(old, new, active, spr)
    assert a.tier2 == b.tier2
    moved = [_expert_of(d, new) for d, _ in a.tier2]
    assert moved == [2, 1]      # coverage restore still precedes top-up


def test_repair_hot_first_within_tier3():
    """Tier-3 reloads come off the wire hottest-first too: when several
    experts lose every live replica, the backup fetch order follows load."""
    spr = 1
    old = np.array([0, 1, 2, 3], np.int32)
    active = np.array([False, False, True, True])
    new = np.array([-1, -1, 0, 1], np.int32)    # 0 and 1 lost all replicas
    backup = BackupStore(1)
    for e in range(4):
        backup.store(e, {"w": np.full((2,), float(e))})
    load = np.array([1.0, 80.0, 1.0, 1.0])
    plan = plan_repair(old, new, active, spr, backup=backup, load=load)
    assert [e for _, e in plan.tier3] == [1, 0]  # hotter expert 1 first


def test_repair_empty_world_degenerate():
    """Zero-slot degenerate input produces an empty, well-formed plan."""
    plan = plan_repair(np.array([], np.int32), np.array([], np.int32),
                       np.array([], bool), 1)
    assert plan.tier1 == [] and plan.tier2 == [] and plan.tier3 == []
    assert plan.source_mix() == {"local_reuse": 0, "gpu_relocation": 0,
                                 "dram_reload": 0}
