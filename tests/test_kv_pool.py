"""Paged KV cache + live migration (repro.serving.kv_cache):

  * the ``KVPool`` protocol is the ONLY pool surface the scheduler /
    engine / frontend / scenario runner touch (source-guard test, same
    discipline as the no-direct-membership-mutation check);
  * paged-pool mechanics — copy-on-extend block claiming, free-pool
    accounting, snapshot/restore pinning, ``migrate()`` relocation and
    the engine's one-gather application of the queued moves;
  * a property test over random allocate/append/release/snapshot/
    restore/migrate/discard sequences: no block is ever aliased by two
    requests, free+used always partitions the pool, and a redeemed
    snapshot restores slot/length/blocks identically (runs under
    hypothesis when installed, a seeded random walk otherwise);
  * migrate-vs-replay equivalence under BOTH dispatch modes: the paged
    pool's drain path delivers the exact token stream the slot pool's
    replay path does, with ``tokens_recomputed == 0`` and MIGRATED
    (never RESUMED) client events;
  * the AdminGateway ``kv`` status section and the registry-level
    ``rolling_maintenance_drain`` acceptance: zero recompute, pages
    moved, a nonzero ``kv-migrate`` phase, invariants green.
"""
import inspect
import json
import random
import re

import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import make_initial_membership
from repro.core.reintegration import WarmupCostModel
from repro.models import init_params
from repro.runtime.elastic import ElasticEPRuntime
from repro.runtime.scenario_runner import run_scenario
from repro.serving.api import ServingFrontend
from repro.serving.engine import ServingEngine
from repro.serving.events import StreamEvent, validate_stream
from repro.serving.kv_cache import (
    KVPool,
    PagedKVPool,
    SlotKVPool,
    make_pool,
)


def _frontend(kv_pool=None, dispatch=None, world=8, seed=0, max_batch=4,
              max_len=64, fixed_membership=False):
    cfg = get_config("mixtral-8x22b").reduced()
    table = make_initial_membership(world, cfg.moe.num_experts, 1)
    params = init_params(cfg, jax.random.key(seed), jnp.float32,
                         table.slot_to_expert, table.num_slots)
    rt = ElasticEPRuntime(cfg, params, table, dispatch=dispatch,
                          warmup_model=WarmupCostModel(1, 1, 2, 1))
    eng = ServingEngine(rt, max_batch=max_batch, max_len=max_len,
                        fixed_membership=fixed_membership, kv_pool=kv_pool)
    return rt, eng, ServingFrontend(eng)


# ---------------------------------------------------------------------------
# The protocol boundary
# ---------------------------------------------------------------------------

def test_both_pools_satisfy_the_protocol_and_factory_selects():
    slot = make_pool("slot", 4, 32)
    paged = make_pool("paged", 4, 32, block_size=8)
    assert isinstance(slot, SlotKVPool) and isinstance(slot, KVPool)
    assert isinstance(paged, PagedKVPool) and isinstance(paged, KVPool)
    assert not slot.supports_migration and paged.supports_migration
    with pytest.raises(ValueError):
        make_pool("mmap", 4, 32)
    # the ArchConfig switch is validated at construction
    cfg = get_config("mixtral-8x22b")
    assert cfg.kv_pool in ("slot", "paged") and cfg.kv_block_size > 0


def test_source_guard_pool_internals_stay_private():
    """The scheduler, engine, frontend and scenario runner speak KVPool
    only — no reaching into ``lengths``/``owner``/``free`` arrays or any
    underscore-private pool state. This is what makes the slot/paged
    switch an ArchConfig flag instead of a fork."""
    from repro.runtime import scenario_runner
    from repro.serving import api, engine, scheduler
    for mod in (scheduler, engine, api, scenario_runner):
        src = inspect.getsource(mod)
        assert not re.search(r"\bkv\.(lengths|owner|free)\b", src), \
            f"{mod.__name__} touches pool-internal arrays"
        assert not re.search(r"\bkv\._", src), \
            f"{mod.__name__} touches private pool state"


# ---------------------------------------------------------------------------
# Paged-pool mechanics
# ---------------------------------------------------------------------------

def test_copy_on_extend_claims_blocks_at_boundaries():
    pool = PagedKVPool(num_slots=2, max_len=32, block_size=4)
    slot = pool.allocate(7, context_len=6)          # ceil(6/4) = 2 blocks
    assert slot is not None
    st = pool.stats()
    assert st["per_request_pages"] == {"7": 2}
    assert st["blocks_used"] == 2
    pool.append(slot)                               # 7 resident: still 2
    pool.append(slot)                               # 8 resident: still 2
    assert pool.stats()["per_request_pages"]["7"] == 2
    assert pool.block_appends == 0
    pool.append(slot)                               # 9 resident: 3rd block
    assert pool.stats()["per_request_pages"]["7"] == 3
    assert pool.block_appends == 1
    # set_length grows coverage too (replay bookkeeping), never shrinks
    pool.set_length(slot, 13)
    assert pool.stats()["per_request_pages"]["7"] == 4
    pool.set_length(slot, 2)
    assert pool.stats()["per_request_pages"]["7"] == 4


def test_allocate_exhaustion_and_never_fit():
    pool = PagedKVPool(num_slots=2, max_len=16, block_size=4)
    assert pool.allocate(0, 4) is not None
    assert pool.allocate(1, 4) is not None
    assert pool.allocate(2, 4) is None              # full: queue, don't raise
    with pytest.raises(ValueError):
        pool.allocate(3, context_len=8, reserve=100)   # can NEVER fit
    assert not pool.fits(8, 100) and pool.fits(8, 8)


def test_release_returns_blocks_and_fragmentation_accounting():
    pool = PagedKVPool(num_slots=4, max_len=16, block_size=4)
    a = pool.allocate(0, 5)                         # 2 blocks, 5 resident
    b = pool.allocate(1, 4)                         # 1 block, 4 resident
    st = pool.stats()
    assert st["blocks_free"] + st["blocks_used"] == st["blocks_total"]
    assert st["blocks_used"] == 3
    # fragmentation = 1 - resident/capacity = 1 - 9/12
    assert abs(st["fragmentation"] - (1 - 9 / 12)) < 1e-9
    pool.release(a)
    st = pool.stats()
    assert st["blocks_used"] == 1 and st["slots_free"] == 3
    assert pool.owner_of(a) == -1 and pool.owner_of(b) == 1
    assert pool.active_slots() == [b]


def test_snapshot_pins_restore_redeems_discard_frees():
    pool = PagedKVPool(num_slots=2, max_len=16, block_size=4)
    slot = pool.allocate(5, 6)
    snap = pool.snapshot(5)
    assert snap.rid == 5 and snap.slot == slot
    assert snap.length == 6 and snap.pages == 2
    # pinned: out of the active set, immune to release/release_all
    assert pool.active_slots() == []
    pool.release(slot)
    assert pool.release_all() == []
    assert pool.stats()["pinned"] == 1
    assert pool.stats()["blocks_used"] == 2         # pages survive intact
    restored = pool.restore(snap)
    assert restored == slot
    assert pool.owner_of(slot) == 5 and pool.length_of(slot) == 6
    assert pool.stats()["pinned"] == 0
    assert pool.migrations == 1 and pool.pages_moved == 2
    # a second redeem of the same snapshot reports residency gone
    assert pool.restore(snap) is None
    # discard path: pinned state returns to the free pools
    pool2 = PagedKVPool(num_slots=2, max_len=16, block_size=4)
    s2 = pool2.allocate(9, 8)
    snap2 = pool2.snapshot(9)
    pool2.discard(snap2)
    assert pool2.stats()["blocks_used"] == 0
    assert pool2.stats()["slots_free"] == 2
    assert s2 in [pool2.allocate(10, 4), pool2.allocate(11, 4)]


def test_migrate_relocates_pinned_pages_and_queues_one_move():
    pool = PagedKVPool(num_slots=4, max_len=16, block_size=4)
    src = pool.allocate(3, 7)                       # 2 blocks in slot src
    pool.snapshot(3)
    dst = next(s for s in range(4) if s != src and pool.owner_of(s) < 0)
    moved = pool.migrate(3, dst)
    assert moved.slot == dst and moved.length == 7 and moved.pages == 2
    # dst identity blocks, src residency freed
    assert moved.blocks == tuple(dst * pool.blocks_per_slot + i
                                 for i in range(2))
    assert pool.take_moves() == [(src, dst)]
    assert pool.take_moves() == []                  # drained
    restored = pool.restore(moved)
    assert restored == dst
    assert pool.owner_of(dst) == 3 and pool.length_of(dst) == 7
    assert pool.owner_of(src) == -1
    st = pool.stats()
    assert st["blocks_free"] + st["blocks_used"] == st["blocks_total"]


def test_slot_pool_snapshot_loses_residency():
    """The slot pool keeps the legacy semantics: snapshot releases the
    slot (cache rows get reused), restore reports the content gone and
    the caller replays through chunk-1 prefill."""
    pool = SlotKVPool(num_slots=2, max_len=16)
    slot = pool.allocate(4, 6)
    snap = pool.snapshot(4)
    assert snap.length == 6 and snap.pages == 0
    assert pool.restore(snap) is None
    assert slot in pool.free                        # released at snapshot
    assert pool.take_moves() == []
    assert pool.stats()["pool"] == "slot"


# ---------------------------------------------------------------------------
# Property: random op sequences never alias a block, never leak one
# ---------------------------------------------------------------------------

def _check_invariants(pool):
    seen = []
    for s, table in pool._tables.items():
        seen.extend(table)
        rid = pool.owner_of(s)
        assert rid >= 0, f"slot {s} holds a table but no owner"
        assert len(table) >= max(1, -(-pool.length_of(s) // pool.block_size))
    assert len(seen) == len(set(seen)), "a block is aliased by two tables"
    assert sorted(seen + list(pool._free_blocks)) == \
        list(range(pool.num_blocks)), "block leak: free+held != pool"
    st = pool.stats()
    assert st["blocks_free"] + st["blocks_used"] == st["blocks_total"]
    for rid, snap in pool._pinned.items():
        assert tuple(pool._tables[snap.slot]) == snap.blocks


def _random_walk(seed: int, steps: int = 120) -> None:
    rng = random.Random(seed)
    pool = PagedKVPool(num_slots=4, max_len=24, block_size=4)
    next_rid = 0
    active: dict[int, int] = {}                    # rid -> slot
    pinned: dict[int, object] = {}                 # rid -> snapshot
    for _ in range(steps):
        ops = ["allocate"]
        if active:
            ops += ["append", "release", "snapshot"]
        if pinned:
            ops += ["restore", "discard"]
            if pool._free_slots:
                ops.append("migrate")
        op = rng.choice(ops)
        if op == "allocate":
            slot = pool.allocate(next_rid, rng.randint(1, 12))
            if slot is not None:
                active[next_rid] = slot
                next_rid += 1
        elif op == "append":
            rid = rng.choice(sorted(active))
            if pool.length_of(active[rid]) < pool.max_len:
                pool.append(active[rid])
        elif op == "release":
            rid = rng.choice(sorted(active))
            pool.release(active.pop(rid))
        elif op == "snapshot":
            rid = rng.choice(sorted(active))
            active.pop(rid)
            pinned[rid] = pool.snapshot(rid)
        elif op == "migrate":
            rid = rng.choice(sorted(pinned))
            dst = rng.choice(pool._free_slots)
            pinned[rid] = pool.migrate(rid, dst)
        elif op == "restore":
            rid = rng.choice(sorted(pinned))
            snap = pinned.pop(rid)
            slot = pool.restore(snap)
            # byte-identity contract: same slot, same resident length,
            # same physical blocks as the snapshot named
            assert slot == snap.slot
            assert pool.length_of(slot) == snap.length
            assert tuple(pool._tables[slot]) == snap.blocks
            assert pool.owner_of(slot) == rid
            active[rid] = slot
        elif op == "discard":
            rid = rng.choice(sorted(pinned))
            pool.discard(pinned.pop(rid))
        moves = pool.take_moves()
        assert len(moves) == len(set(moves))
        _check_invariants(pool)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 100_000))
    def test_paged_pool_random_sequences_property(seed):
        _random_walk(seed)
except ImportError:                                 # seeded fallback
    def test_paged_pool_random_sequences_property():
        for seed in range(40):
            _random_walk(seed)


# ---------------------------------------------------------------------------
# Engine integration: the gather, the MIGRATED stream, the equivalence
# ---------------------------------------------------------------------------

def test_engine_applies_migrate_moves_as_one_gather_tokens_identical():
    """Relocating a pinned request's pages into another slot (the queued
    (src, dst) move applied as one jitted gather over the donated cache
    buffers) continues decode with byte-identical KV: the token stream
    equals an uninterrupted run's, with zero recompute."""
    ref_rt, ref_eng, ref_fe = _frontend(kv_pool="paged")
    ref = ref_fe.submit([3, 1, 4], max_new=24)
    ref_fe.run(max_steps=500)
    assert ref.outcome == "FINISHED" and len(ref.tokens) == 24

    rt, eng, fe = _frontend(kv_pool="paged")
    h = fe.submit([3, 1, 4], max_new=24)
    for _ in range(10):
        fe.step()
    pre = list(h.tokens)
    assert len(pre) > 2
    eng.sched.migrate_inflight(now=rt.clock.now(), epoch=rt.epoch)
    src = next(s for s in range(eng.kv.num_slots) if s in eng.kv._pinned_slots)
    dst = next(s for s in range(eng.kv.num_slots) if s in eng.kv._free_slots)
    eng.kv.migrate(0, dst)
    fe.run(max_steps=500)
    assert h.outcome == "FINISHED"
    assert h.tokens == ref.tokens
    assert h.tokens[:len(pre)] == pre
    st = eng.sched.stats
    assert st.tokens_recomputed == 0 and st.migrated == 1
    assert st.tokens_migrated > 0
    assert eng.kv.owner_of(src) in (-1, 0) and eng.compile_count() == 1
    kinds = [e.kind for e in h.events]
    assert "MIGRATED" in kinds and "RESUMED" not in kinds
    assert not validate_stream(h.events)


@pytest.mark.parametrize("dispatch", ["dense", "ragged"])
def test_drain_migrate_vs_replay_equivalence(dispatch):
    """The api_redesign acceptance: under both dispatch modes, a planned
    drain over the paged pool MIGRATES in-flight KV (zero recompute,
    MIGRATED events) and over the slot pool REPLAYS it (recompute > 0,
    RESUMED events) — and both deliver the identical token streams."""
    streams = {}
    for pool in ("paged", "slot"):
        rt, eng, fe = _frontend(kv_pool=pool, dispatch=dispatch)
        handles = [fe.submit([1] * 6, max_new=24) for _ in range(4)]
        for _ in range(8):
            fe.step()
        assert eng.sched.inflight > 0
        fe.admin.execute({"cmd": "drain", "ranks": [2]})
        fe.run(until=rt.clock.now() + 120.0, max_steps=20_000)
        st = eng.sched.stats
        assert st.finished == 4 and st.failed == 0
        assert st.preempted == 4
        assert fe.metrics()["error_events"] == 0
        assert not fe.stream_violations()
        assert eng.compile_count() == 1
        streams[pool] = [list(h.tokens) for h in handles]
        kinds = [e.kind for h in handles for e in h.events]
        if pool == "paged":
            assert st.tokens_recomputed == 0 and st.migrated == 4
            assert st.tokens_migrated > 0
            assert "MIGRATED" in kinds and "RESUMED" not in kinds
            assert fe.metrics()["tokens_migrated"] == st.tokens_migrated
            # every stream brackets the drain as PREEMPTED -> MIGRATED ->
            # STALL_END, with detail carrying the page manifest view
            for h in handles:
                ks = [e.kind for e in h.events]
                mi = ks.index("MIGRATED")
                assert ks[mi - 1] == "PREEMPTED" and ks[mi + 1] == "STALL_END"
                ev = h.events[mi]
                assert ev.detail["pages"] > 0 and ev.detail["tokens"] > 0
                assert ev.detail["epoch"] >= ev.detail["snapshot_epoch"] >= 0
        else:
            assert st.tokens_recomputed > 0 and st.migrated == 0
            assert "RESUMED" in kinds and "MIGRATED" not in kinds
    assert streams["paged"] == streams["slot"]      # migrate == replay


def test_admin_status_kv_section_round_trips():
    rt, eng, fe = _frontend(kv_pool="paged")
    handles = [fe.submit([1] * 6, max_new=30) for _ in range(3)]
    for _ in range(6):
        fe.step()
    raw = fe.admin.execute_json('{"cmd": "status"}')
    kv = json.loads(raw)["result"]["kv"]
    assert kv["pool"] == "paged" and kv["block_size"] > 0
    assert kv["blocks_free"] + kv["blocks_used"] == kv["blocks_total"]
    assert kv["slots_total"] == 4 and kv["pinned"] == 0
    assert len(kv["per_request_pages"]) == 3
    assert all(p >= 1 for p in kv["per_request_pages"].values())
    assert 0.0 <= kv["fragmentation"] <= 1.0
    assert kv["migrations"] == 0 and kv["pages_moved"] == 0
    fe.admin.execute({"cmd": "drain", "ranks": [2]})
    fe.run(until=rt.clock.now() + 120.0, max_steps=20_000)
    kv = fe.admin.execute({"cmd": "status"})["result"]["kv"]
    assert kv["migrations"] == 3 and kv["pages_moved"] > 0
    assert all(h.outcome == "FINISHED" for h in handles)


def test_validate_stream_migrated_rules():
    def ev(kind, t, seq, index=-1, **detail):
        return StreamEvent(kind=kind, t=t, seq=seq, index=index,
                           detail=detail)
    ok = [ev("PREEMPTED", 0.1, 0, cause="drain"),
          ev("MIGRATED", 0.2, 1, epoch=2, pages=2),
          ev("STALL_END", 0.3, 2), ev("TOKEN", 0.3, 3, 0),
          ev("FINISHED", 0.4, 4)]
    assert validate_stream(ok) == []
    # MIGRATED only lives inside an open stall window
    assert validate_stream([ev("MIGRATED", 0.1, 0)])
    assert validate_stream([ev("TOKEN", 0.1, 0, 0), ev("MIGRATED", 0.2, 1)])
    # migrate and replay are mutually exclusive within one window
    assert validate_stream([ev("PREEMPTED", 0.1, 0),
                            ev("MIGRATED", 0.2, 1),
                            ev("RESUMED", 0.3, 2)])
    assert validate_stream([ev("STALL_BEGIN", 0.1, 0, cause="fault"),
                            ev("RESUMED", 0.2, 1),
                            ev("MIGRATED", 0.3, 2)])
    # ...but separate windows may use different flavors
    two = [ev("PREEMPTED", 0.1, 0), ev("MIGRATED", 0.2, 1),
           ev("STALL_END", 0.3, 2), ev("TOKEN", 0.3, 3, 0),
           ev("STALL_BEGIN", 0.4, 4, cause="fault"),
           ev("RESUMED", 0.5, 5), ev("STALL_END", 0.6, 6),
           ev("TOKEN", 0.6, 7, 1), ev("FINISHED", 0.7, 8)]
    assert validate_stream(two) == []


def test_rolling_maintenance_drain_migrates_registry_level():
    """The ISSUE acceptance on the registry: the pure planned-drain
    scenario recomputes NOTHING — its KV pages moved to the survivors
    inside the drain windows (nonzero kv-migrate phase, pages in the
    drain record) — with every invariant green."""
    res = run_scenario("rolling_maintenance_drain")
    assert res.invariants_ok and not res.stream_violations
    assert res.client["tokens_recomputed"] == 0
    assert res.client["tokens_migrated"] > 0
    assert res.client["migrations"] > 0
    assert res.requests_migrated > 0
    assert res.kv_pages_moved > 0
    assert res.kv_migrate_s > 0
    summary = res.summary()
    assert summary["compile_count"] == 1
    assert summary["kv_pages_moved"] == res.kv_pages_moved
    assert summary["tokens_migrated"] == res.client["tokens_migrated"]
    drains = [e for e in res.timeline if e["kind"] == "drain"]
    assert drains and any(e["detail"].get("kv_pages_moved", 0) > 0
                          for e in drains)
    assert any(sp["phase"] == "kv-migrate" for sp in res.spans)
    json.dumps(summary)                             # BENCH row serializable
