"""Cross-session prefix sharing (repro.serving.prefix_cache + the
prefix-enabled PagedKVPool + the engine/scheduler threading):

  * radix-trie mechanics — rolling block hashes, longest-chain match with
    token-tuple verification, refcounted acquire/release, LRU leaf
    eviction, dedup on re-insert;
  * pool partition discipline: every physical block is exactly one of
    free / held (private to one table or snapshot) / shared (registered
    in the trie); any multi-referenced block is shared; non-borrowed
    table entries are identity blocks (copy-on-write by construction —
    a request can only ever write its own row);
  * a property test over random allocate/cache/append/release/snapshot/
    restore/migrate/discard walks against those invariants (hypothesis
    when the dev extra is installed, seeded walks otherwise);
  * evict -> re-insert: a reclaimed prefix re-caches content-identical;
  * engine integration — a 64-session prefix-heavy storm skips >= 50%
    of all prompt tokens, streams byte-identical to a cache-off run of
    the same sessions, one compilation;
  * planned drain with shared pages live: zero recompute, the manifest
    dedupes shared physical pages (kv_bytes_moved strictly below the
    cache-off logical baseline on the same workload);
  * the AdminGateway ``kv.prefix`` status section round-trips as JSON.
"""
import json
import random

import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import make_initial_membership
from repro.core.reintegration import WarmupCostModel
from repro.models import init_params
from repro.runtime.elastic import ElasticEPRuntime
from repro.serving.api import ServingFrontend
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import PagedKVPool, SlotKVPool, make_pool
from repro.serving.prefix_cache import PrefixCache, roll_hash


def _frontend(max_batch=4, max_len=32, prefix_cache=None, seed=0,
              kv_pool="paged"):
    import dataclasses
    cfg = get_config("mixtral-8x22b").reduced()
    if prefix_cache is not None:
        cfg = dataclasses.replace(cfg, prefix_cache=prefix_cache)
    table = make_initial_membership(8, cfg.moe.num_experts, 1)
    params = init_params(cfg, jax.random.key(seed), jnp.float32,
                         table.slot_to_expert, table.num_slots)
    rt = ElasticEPRuntime(cfg, params, table,
                          warmup_model=WarmupCostModel(1, 1, 2, 1))
    eng = ServingEngine(rt, max_batch=max_batch, max_len=max_len,
                        kv_pool=kv_pool)
    return rt, eng, ServingFrontend(eng)


# ---------------------------------------------------------------------------
# Trie mechanics
# ---------------------------------------------------------------------------

def test_roll_hash_chains_and_separates():
    a = roll_hash(None, (1, 2, 3, 4))
    b = roll_hash(None, (1, 2, 3, 4))
    c = roll_hash(None, (4, 3, 2, 1))
    assert a == b != c
    # chained: the parent key folds into the child block's hash
    assert roll_hash(a, (5, 6)) == roll_hash(b, (5, 6))
    assert roll_hash(a, (5, 6)) != roll_hash(c, (5, 6))
    assert roll_hash(a, (5, 6)) != roll_hash(None, (5, 6))


def test_match_insert_refcount_and_lru_eviction():
    pc = PrefixCache(block_size=4)
    blocks = {0: 10, 1: 11, 2: 12}
    created = pc.insert((1, 2, 3, 4, 5, 6, 7, 8, 9), blocks.get)
    assert [n.block for n in created] == [10, 11]    # partial 3rd block: no
    assert len(pc) == 2 and pc.blocks() == {10, 11}
    # re-insert dedupes, nothing new
    assert pc.insert((1, 2, 3, 4, 5, 6, 7, 8), blocks.get) == []
    chain = pc.match((1, 2, 3, 4, 5, 6, 7, 8, 99))
    assert [n.block for n in chain] == [10, 11]
    assert len(pc.match((1, 2, 3, 4, 99))) == 1
    assert pc.match((9, 9, 9, 9)) == []
    st = pc.stats()
    assert st["hits"] == 2 and st["misses"] == 1
    assert st["tokens_matched"] == 12
    # refcounts pin against eviction
    pc.acquire(chain)
    assert all(n.refs == 1 for n in chain)
    assert pc.evictable_leaf() is None               # leaf is referenced
    pc.release(chain[1])
    leaf = pc.evictable_leaf()
    assert leaf is chain[1]                          # deepest refs-0 LEAF
    pc.remove(leaf)
    assert len(pc) == 1 and pc.stats()["evictions"] == 1
    # the surviving node is still referenced; nothing evictable
    assert pc.evictable_leaf() is None
    pc.release(chain[0])
    assert pc.evictable_leaf() is chain[0]


def test_match_verifies_tokens_not_just_hashes():
    pc = PrefixCache(block_size=2)
    pc.insert((1, 2, 3, 4), {0: 5, 1: 6}.get)
    node = pc.match((1, 2), count=False)[0]
    node.tokens = (7, 8)        # simulate a hash collision / stale node
    assert pc.match((1, 2), count=False) == []


# ---------------------------------------------------------------------------
# Pool partition / COW invariants
# ---------------------------------------------------------------------------

def _check_prefix_invariants(pool: PagedKVPool):
    shared = set(pool._shared)
    refs: dict[int, int] = {}
    held = set()
    for s, table in pool._tables.items():
        if s in pool._pinned_slots:
            # a pinned slot's table stays resident for the eventual
            # restore, but its authoritative reference is the snapshot
            # (counted below) — counting both would double-count
            continue
        fcount = pool._foreign.get(s, 0)
        for i, b in enumerate(table):
            refs[b] = refs.get(b, 0) + 1
            if b not in shared:
                held.add(b)
            if i >= fcount:
                # COW by construction: every position this request can
                # write lives in its own identity blocks
                assert b == s * pool.blocks_per_slot + i, (
                    f"slot {s} depth {i}: non-borrowed entry {b} is not "
                    f"the identity block")
    for snap in pool._pinned.values():
        for b in snap.blocks:
            refs[b] = refs.get(b, 0) + 1
            if b not in shared:
                held.add(b)
    free = set(pool._free_blocks)
    # free / held / shared partition the physical pool
    assert not (free & held) and not (free & shared) and not (held & shared)
    assert free | held | shared == set(range(pool.num_blocks)), \
        "block leak: free+held+shared != pool"
    # no two writers: any block referenced more than once is shared
    for b, n in refs.items():
        if n > 1:
            assert b in shared, f"private block {b} aliased by {n} tables"
    # trie refcounts equal the live reference counts exactly
    if pool.prefix is not None:
        trie_blocks = {n.block for n in pool.prefix._iter_nodes()}
        assert trie_blocks == shared
        for node in pool.prefix._iter_nodes():
            assert node.refs == refs.get(node.block, 0), (
                f"block {node.block}: trie refs {node.refs} != "
                f"{refs.get(node.block, 0)} live references")
    st = pool.stats()
    assert (st["blocks_free"] + st["blocks_held"] + st["blocks_shared"]
            == st["blocks_total"])
    assert st["blocks_shared"] == len(shared)


def test_shared_prefix_partitions_pool_and_parks_donor():
    pool = PagedKVPool(num_slots=4, max_len=32, block_size=4,
                       prefix_cache=True)
    prompt = tuple(range(1, 11))                     # 10 tokens: 2 full + 1
    s0 = pool.allocate(0, len(prompt), prompt=prompt)
    assert pool.prefix_matched(s0) == 0              # cold cache
    assert pool.cache_prompt(s0, prompt) == 2        # the 2 full blocks
    _check_prefix_invariants(pool)
    st = pool.stats()
    assert st["blocks_shared"] == 2
    assert st["prefix"]["cache_resident_slots"] == 1

    s1 = pool.allocate(1, len(prompt), prompt=prompt)
    assert pool.prefix_matched(s1) == 8              # 2 blocks x 4 tokens
    # table = [donor shared, donor shared, own identity 3rd block]
    t = pool._tables[s1]
    assert t[:2] == pool._tables[s0][:2]
    assert t[2] == s1 * pool.blocks_per_slot + 2
    # one whole-row donor gather queued, from the deepest node's home
    assert pool.take_moves() == [(s0, s1)]
    _check_prefix_invariants(pool)
    assert pool.stats()["prefix"]["hits"] == 1
    # physical vs logical inflight: 2 shared pages counted once
    assert pool.inflight_pages_logical() - pool.inflight_pages() == 2

    # releases drop references; pages stay cached; donor slot stays parked
    pool.release(s1)
    pool.release(s0)
    _check_prefix_invariants(pool)
    st = pool.stats()
    assert st["blocks_shared"] == 2 and st["slots_free"] == 3
    # a fresh request still matches the now cache-only pages
    s2 = pool.allocate(2, len(prompt), prompt=prompt)
    assert pool.prefix_matched(s2) == 8
    _check_prefix_invariants(pool)


def test_eviction_unparks_donor_and_reinsert_is_content_identical():
    # 2 slots x 3 blocks: tiny pool, heavy pressure (max_len leaves
    # headroom for one decode token past the 8-token prompts)
    pool = PagedKVPool(num_slots=2, max_len=12, block_size=4,
                       prefix_cache=True)
    pa = (1, 2, 3, 4, 5, 6, 7, 8)
    s0 = pool.allocate(0, len(pa), prompt=pa)
    pool.cache_prompt(s0, pa)
    pool.release(s0)                                 # parked cache-resident
    assert pool.stats()["slots_free"] == 1
    chain_before = [(n.key, tuple(n.tokens), n.depth)
                    for n in pool.prefix.match(pa, count=False)]
    assert len(chain_before) == 2
    # two fresh non-matching requests force reclaim of the parked slot
    s1 = pool.allocate(1, 8, prompt=(9, 9, 9, 9, 9, 9, 9, 9))
    s2 = pool.allocate(2, 8, prompt=(8, 8, 8, 8, 8, 8, 8, 8))
    assert s1 is not None and s2 is not None and s2 == s0
    assert pool.stats()["prefix"]["evictions"] == 2
    assert pool.prefix.match(pa, count=False) == []  # fully evicted
    _check_prefix_invariants(pool)
    # re-insert the same prompt: the rebuilt chain is content-identical
    # (same rolling keys, same token blocks, same depths)
    pool.release(s2)
    s3 = pool.allocate(3, len(pa), prompt=pa)
    pool.cache_prompt(s3, pa)
    chain_after = [(n.key, tuple(n.tokens), n.depth)
                   for n in pool.prefix.match(pa, count=False)]
    assert chain_after == chain_before
    _check_prefix_invariants(pool)


def test_prefix_disabled_and_slot_pool_are_inert():
    paged = PagedKVPool(num_slots=2, max_len=16, block_size=4)
    slot = SlotKVPool(num_slots=2, max_len=16)
    prompt = tuple(range(1, 9))
    for pool in (paged, slot):
        s = pool.allocate(0, len(prompt), prompt=prompt)
        assert pool.match_prefix(prompt) == 0
        assert pool.prefix_matched(s) == 0
        assert pool.cache_prompt(s, prompt) == 0
        assert pool.stats()["prefix"] == {"enabled": False}
    assert make_pool("paged", 2, 16, prefix_cache=True).prefix is not None
    assert make_pool("paged", 2, 16).prefix is None


# ---------------------------------------------------------------------------
# Property: random op walks never break the partition / COW / refcounts
# ---------------------------------------------------------------------------

SHARED_PROMPTS = [tuple(range(100, 100 + n)) for n in (8, 12, 9)]


def _prefix_walk(seed: int, steps: int = 150) -> None:
    rng = random.Random(seed)
    pool = PagedKVPool(num_slots=4, max_len=32, block_size=4,
                       prefix_cache=True)
    next_rid = 0
    active: dict[int, int] = {}                     # rid -> slot
    prompts: dict[int, tuple] = {}                  # rid -> prompt
    pinned: dict[int, object] = {}
    for _ in range(steps):
        ops = ["allocate", "allocate"]
        if active:
            ops += ["append", "release", "cache", "snapshot"]
        if pinned:
            ops += ["restore", "discard"]
            if pool._free_slots:
                ops.append("migrate")
        op = rng.choice(ops)
        if op == "allocate":
            if rng.random() < 0.6:
                prompt = rng.choice(SHARED_PROMPTS)
            else:
                prompt = tuple(rng.randrange(1, 50)
                               for _ in range(rng.randint(1, 12)))
            slot = pool.allocate(next_rid, len(prompt), prompt=prompt)
            if slot is not None:
                assert pool.prefix_matched(slot) <= len(prompt)
                active[next_rid] = slot
                prompts[next_rid] = prompt
                next_rid += 1
        elif op == "cache":
            rid = rng.choice(sorted(active))
            pool.cache_prompt(active[rid], prompts[rid])
        elif op == "append":
            rid = rng.choice(sorted(active))
            if pool.length_of(active[rid]) < pool.max_len:
                pool.append(active[rid])
        elif op == "release":
            rid = rng.choice(sorted(active))
            pool.release(active.pop(rid))
        elif op == "snapshot":
            rid = rng.choice(sorted(active))
            active.pop(rid)
            pinned[rid] = pool.snapshot(rid)
        elif op == "migrate":
            rid = rng.choice(sorted(pinned))
            dst = rng.choice(pool._free_slots)
            pinned[rid] = pool.migrate(rid, dst)
        elif op == "restore":
            rid = rng.choice(sorted(pinned))
            snap = pinned.pop(rid)
            slot = pool.restore(snap)
            assert slot == snap.slot
            assert tuple(pool._tables[slot]) == snap.blocks
            active[rid] = slot
        elif op == "discard":
            rid = rng.choice(sorted(pinned))
            pool.discard(pinned.pop(rid))
        moves = pool.take_moves()
        assert len(moves) == len(set(moves))
        _check_prefix_invariants(pool)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 100_000))
    def test_prefix_pool_random_walk_property(seed):
        _prefix_walk(seed)
except ImportError:                                 # seeded fallback
    def test_prefix_pool_random_walk_property():
        for seed in range(40):
            _prefix_walk(seed)


# ---------------------------------------------------------------------------
# Engine gate
# ---------------------------------------------------------------------------

def test_prefix_cache_supported_gates_on_layout():
    sup = ServingEngine.prefix_cache_supported
    mixtral = get_config("mixtral-8x22b").reduced()     # swa, window 32
    assert sup(mixtral, 32) and sup(mixtral, 16)
    assert not sup(mixtral, 64)          # ring buffer wraps past the window
    assert not sup(get_config("jamba-v0.1-52b").reduced(), 32)   # recurrent
    assert not sup(get_config("whisper-small").reduced(), 32)    # encoder
    assert not sup(get_config("internvl2-26b").reduced(), 32)    # frontend
    assert sup(get_config("yi-34b").reduced(), 32)               # dense gqa


def test_engine_honors_config_toggle_and_gate():
    _, eng_on, _ = _frontend(max_len=32, prefix_cache=True)
    _, eng_off, _ = _frontend(max_len=32, prefix_cache=False)
    _, eng_swa, _ = _frontend(max_len=64, prefix_cache=True)
    _, eng_slot, _ = _frontend(max_len=32, prefix_cache=True, kv_pool="slot")
    assert eng_on.prefix_enabled and eng_on.kv.prefix is not None
    assert not eng_off.prefix_enabled and eng_off.kv.prefix is None
    assert not eng_swa.prefix_enabled            # window < max_len: wraps
    assert not eng_slot.prefix_enabled


# ---------------------------------------------------------------------------
# Engine integration: skip >= 50%, byte-identical streams, one compile
# ---------------------------------------------------------------------------

def test_prefix_storm_64_sessions_skips_half_and_streams_identical():
    from repro.serving.loadgen import WorkloadSpec, build_sessions, run_storm
    spec = WorkloadSpec(rate_rps=16.0, duration_s=30.0, n_max=64,
                        prompt_mean=2, prompt_max=4, out_mean=3, out_max=6,
                        vocab=256, prefix_groups=1, prefix_len=16)
    sessions = build_sessions(spec, seed=7)
    assert len(sessions) == 64
    total_prompt = sum(len(s.prompt) for s in sessions)

    streams = {}
    for enabled in (True, False):
        rt, eng, fe = _frontend(max_batch=8, max_len=32,
                                prefix_cache=enabled)
        results = run_storm(fe, sessions)
        assert all(r.outcome == "FINISHED" for r in results)
        assert not fe.stream_violations()
        assert eng.compile_count() == 1
        streams[enabled] = {
            r.session.sid: tuple(e.token for e in r.events
                                 if e.kind == "TOKEN")
            for r in results}
        m = fe.metrics()
        if enabled:
            assert eng.prefix_enabled
            # the tentpole acceptance: most prefill work never re-runs
            assert m["tokens_prefill_skipped"] >= 0.5 * total_prompt
            assert m["prefix_hits"] >= 32
            assert 0.0 < m["prefix_hit_rate"] <= 1.0
            _check_prefix_invariants(eng.kv)
        else:
            assert m["tokens_prefill_skipped"] == 0
            assert m["prefix_hits"] == 0
    # the cache is invisible in the output: byte-identical streams
    assert streams[True] == streams[False]


def test_full_prompt_hit_still_replays_last_token():
    """A prompt matching ENTIRELY (every block cached) must still replay
    its final token — the first decode step needs that position's logits.
    skip == replay_len - 1, never replay_len."""
    rt, eng, fe = _frontend(max_batch=4, max_len=32, prefix_cache=True)
    prompt = list(range(1, 17))                      # exactly one block
    a = fe.submit(prompt, max_new=4)
    fe.run(max_steps=200)
    assert a.outcome == "FINISHED"
    b = fe.submit(prompt, max_new=4)
    fe.run(max_steps=200)
    assert b.outcome == "FINISHED"
    assert b.tokens == a.tokens                      # same model, same KV
    st = eng.sched.stats
    assert st.prefix_hits == 1
    assert st.tokens_prefill_skipped == len(prompt) - 1


# ---------------------------------------------------------------------------
# Drain with shared pages: zero recompute, deduped manifest
# ---------------------------------------------------------------------------

def _drain_with_shared_pages(enabled: bool):
    rt, eng, fe = _frontend(max_batch=8, max_len=32, prefix_cache=enabled)
    prompt = list(range(1, 18))                      # 17 tokens: 2 blocks
    donor = fe.submit(prompt, max_new=12)
    for _ in range(len(prompt) + 2):                 # donor prefill done,
        fe.step()                                    # prompt cached
    rest = [fe.submit(prompt, max_new=12) for _ in range(7)]
    for _ in range(4):
        fe.step()
    assert eng.sched.inflight == 8
    if enabled:
        assert eng.kv.stats()["blocks_shared"] > 0
        assert fe.metrics()["prefix_hits"] == 7
    fe.admin.execute({"cmd": "drain", "ranks": [2, 3]})
    fe.run(until=rt.clock.now() + 200.0, max_steps=30_000)
    st = eng.sched.stats
    assert st.finished == 8 and st.failed == 0
    assert fe.metrics()["error_events"] == 0
    assert not fe.stream_violations()
    # the paper's planned-drain gate holds with shared pages live
    assert st.tokens_recomputed == 0
    drains = [e for e in rt.timeline if e.kind == "drain"]
    assert drains
    rec = drains[-1].detail
    streams = [tuple(h.tokens) for h in [donor] + rest]
    return rec, streams


def test_drain_ships_each_shared_page_once():
    rec_on, streams_on = _drain_with_shared_pages(True)
    rec_off, streams_off = _drain_with_shared_pages(False)
    # identical behavior either way (the cache is a pure optimization)
    assert streams_on == streams_off
    # 8 requests x 2 blocks: 16 logical pages; shared dedup collapses the
    # 7 borrowed prefix pages, so the manifest ships strictly less
    assert rec_off.get("kv_pages_deduped", 0) == 0
    assert rec_on["kv_pages_deduped"] > 0
    assert rec_on["kv_pages_moved"] < rec_off["kv_pages_moved"]
    assert rec_on["kv_bytes_moved"] < rec_off["kv_bytes_moved"]
    assert rec_on["kv_bytes_moved"] > 0


# ---------------------------------------------------------------------------
# Admin surface
# ---------------------------------------------------------------------------

def test_admin_status_kv_prefix_section_round_trips():
    rt, eng, fe = _frontend(max_batch=4, max_len=32, prefix_cache=True)
    prompt = list(range(1, 17))
    fe.submit(prompt, max_new=4)
    fe.run(max_steps=200)
    fe.submit(prompt, max_new=4)
    for _ in range(4):
        fe.step()
    raw = fe.admin.execute_json('{"cmd": "status"}')
    doc = json.loads(raw)
    prefix = doc["result"]["kv"]["prefix"]
    assert prefix["enabled"] is True
    assert prefix["nodes"] >= 1
    assert prefix["shared_blocks"] >= 1
    assert prefix["hits"] == 1 and prefix["misses"] >= 1
    assert 0.0 < prefix["hit_rate"] <= 1.0
    assert prefix["tokens_matched"] >= 15
    assert prefix["evictions"] == 0
    assert prefix["cache_resident_slots"] >= 1
    json.dumps(doc)                                  # fully serializable
    # scheduler counters ride the same status document
    sched = doc["result"]["scheduler"]
    assert sched["prefix_hits"] == 1
    assert sched["tokens_prefill_skipped"] == len(prompt) - 1
    # and the disabled flavor reports itself honestly
    _, _, fe_off = _frontend(max_batch=4, max_len=32, prefix_cache=False)
    doc = json.loads(fe_off.admin.execute_json('{"cmd": "status"}'))
    assert doc["result"]["kv"]["prefix"] == {"enabled": False}
