import os
import sys

# tests run single-device (the dry-run alone forces 512 fake devices)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "float32")
