"""Fault-tolerant training driver: train a small MoE LM for a few hundred
steps with periodic checkpoints, crash it mid-run, restart, and verify the
loss curve continues seamlessly (exact data-order recovery).

  PYTHONPATH=src python examples/train_moe_ft.py [--steps 200]
"""
import argparse, sys, os, shutil
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.train.loop import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt", default="/tmp/repro_train_ft")
    args = ap.parse_args()
    shutil.rmtree(args.ckpt, ignore_errors=True)

    cfg = get_config("mixtral-8x22b").reduced()
    tcfg = TrainerConfig(steps=args.steps, checkpoint_every=25,
                         log_every=25, checkpoint_dir=args.ckpt, lr=2e-3)

    crash_at = args.steps // 2
    t1 = Trainer(cfg, tcfg, batch=8, seq_len=64)
    try:
        t1.run(steps=args.steps, fail_at=crash_at)
    except RuntimeError as e:
        print(f"!! {e} — restarting from checkpoint")

    t2 = Trainer(cfg, tcfg, batch=8, seq_len=64)
    assert t2.try_restore(), "no checkpoint found"
    print(f"restored at step {t2.step}")
    t2.run(steps=args.steps - t2.step)
    print(f"final loss {t2.history[-1]['loss']:.4f} at step {t2.step}")


if __name__ == "__main__":
    main()
