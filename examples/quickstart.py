"""Quickstart: build a small elastic MoE instance, serve a few requests,
kill a rank mid-flight, watch it recover and rejoin.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import make_initial_membership
from repro.core.reintegration import WarmupCostModel
from repro.models import init_params
from repro.runtime.elastic import ElasticEPRuntime
from repro.serving.engine import ServingEngine
from repro.serving.request import Request


def main():
    # reduced mixtral: 4 experts, top-2 — simulated 8-rank wide-EP instance
    cfg = get_config("mixtral-8x22b").reduced()
    table = make_initial_membership(world=8, num_experts=cfg.moe.num_experts,
                                    slots_per_rank=1)
    params = init_params(cfg, jax.random.key(0), jnp.float32,
                         table.slot_to_expert, table.num_slots)
    rt = ElasticEPRuntime(cfg, params, table,
                          warmup_model=WarmupCostModel(1, 2, 3, 2))
    eng = ServingEngine(rt, max_batch=4, max_len=48)

    for i in range(8):
        eng.sched.submit(Request(rid=i, prompt=[3, 1, 4, 1, 5],
                                 max_new_tokens=10))

    # fail rank 3 one (simulated) second in
    rt.injector.inject_at(1.0, [3])
    eng.run(until=60.0, max_steps=3000)

    print(f"requests finished : {eng.sched.stats.finished}")
    print(f"tokens generated  : {eng.sched.stats.tokens_out}")
    print(f"compilations      : {eng.compile_count()} "
          f"(one executable across fail/recover/rejoin)")
    print("timeline:")
    for ev in rt.timeline:
        print(f"  t={ev.t:6.2f}s  {ev.kind}")
    assert rt.table.active_mask.all()
    print("instance back at full capacity.")


if __name__ == "__main__":
    main()
