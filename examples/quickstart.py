"""Quickstart: build a small elastic MoE instance, stream a few client
sessions through the serving frontend, kill a rank mid-flight, and watch
the streams ride out the fault as a bounded stall (continuation
semantics) while the rank recovers and rejoins.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import make_initial_membership
from repro.core.reintegration import WarmupCostModel
from repro.models import init_params
from repro.runtime.elastic import ElasticEPRuntime
from repro.serving.api import ServingFrontend
from repro.serving.engine import ServingEngine


def main():
    # reduced mixtral: 4 experts, top-2 — simulated 8-rank wide-EP instance
    cfg = get_config("mixtral-8x22b").reduced()
    table = make_initial_membership(world=8, num_experts=cfg.moe.num_experts,
                                    slots_per_rank=1)
    params = init_params(cfg, jax.random.key(0), jnp.float32,
                         table.slot_to_expert, table.num_slots)
    rt = ElasticEPRuntime(cfg, params, table,
                          warmup_model=WarmupCostModel(1, 2, 3, 2))
    eng = ServingEngine(rt, max_batch=4, max_len=48)
    fe = ServingFrontend(eng)

    handles = [fe.submit([3, 1, 4, 1, 5], max_new=10) for _ in range(8)]

    # fail rank 3 one (simulated) second in
    rt.injector.inject_at(1.0, [3])

    # iterate one stream like a client would: the frontend steps the engine
    # as needed; the others fill in along the way
    for ev in handles[0]:
        print(f"  rid 0  t={ev.t:6.2f}s  {ev.kind}"
              + (f"  index={ev.index} token={ev.token}"
                 if ev.kind == "TOKEN" else f"  {ev.detail}"))
    fe.run(until=60.0, max_steps=3000)   # drain the rest + the rejoin

    st = eng.sched.stats
    print(f"requests finished : {st.finished} "
          f"(failed={st.failed}, suspended={st.suspended})")
    print(f"tokens generated  : {st.tokens_out}")
    print(f"compilations      : {eng.compile_count()} "
          f"(one executable across fail/recover/rejoin)")
    m = fe.metrics()
    print(f"client-perceived  : ttft_p50={m['ttft_p50_s']}s "
          f"stall_max={m['stall_max_s']}s "
          f"recomputed={m['tokens_recomputed']} "
          f"error_events={m['error_events']}")
    print("admin status      :",
          fe.admin.execute_json('{"cmd": "status"}')[:120], "...")
    assert not fe.stream_violations()
    assert rt.table.active_mask.all()
    print("instance back at full capacity; every stream exactly-once.")


if __name__ == "__main__":
    main()
