"""End-to-end serving driver: batched client sessions on a 32-rank
simulated EP instance, a 2-rank correlated failure, EEP recovery (with
fault-transparent continuation — zero client-visible errors) vs the
full-restart baseline (clients see FAILED + retry) — prints both
throughput traces (the Fig. 1 experiment) and the client-perceived view.

  PYTHONPATH=src python examples/serve_with_failover.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax, jax.numpy as jnp

from repro.configs import get_config
from repro.core import make_initial_membership
from repro.models import init_params
from repro.runtime.elastic import ElasticEPRuntime
from repro.serving.api import ServingFrontend
from repro.serving.engine import ServingEngine


def run(fixed_membership: bool):
    cfg = get_config("mixtral-8x22b").reduced()
    table = make_initial_membership(32, cfg.moe.num_experts, 1)
    params = init_params(cfg, jax.random.key(0), jnp.float32,
                         table.slot_to_expert, table.num_slots)
    rt = ElasticEPRuntime(cfg, params, table)
    eng = ServingEngine(rt, max_batch=8, max_len=2048, base_step_time=0.25,
                        fixed_membership=fixed_membership)
    fe = ServingFrontend(eng)
    for _ in range(64):
        fe.submit([1] * 4, max_new=2000)     # outlives the horizon
    rt.injector.inject_at(20.0, [5, 13])
    fe.run(until=420.0, max_steps=20000)
    return rt, eng, fe


def summarize(name, rt, eng, fe, bucket=15.0):
    print(f"--- {name} ---")
    buckets = {}
    for s in eng.trace:
        buckets.setdefault(int(s.t // bucket), []).append(s.tokens_per_s)
    for b in sorted(buckets):
        bar = "#" * int(np.mean(buckets[b]) / 2)
        print(f"  t={b * bucket:5.0f}s  {np.mean(buckets[b]):6.1f} tok/s {bar}")
    for ev in rt.timeline:
        if ev.kind != "start":
            print(f"  event t={ev.t:.1f}s {ev.kind}")
    m = fe.metrics()
    print(f"  client view: error_events={m['error_events']} "
          f"stall_events={m['stall_events']} stall_max={m['stall_max_s']}s "
          f"recomputed={m['tokens_recomputed']}")


def main():
    rt, eng, fe = run(fixed_membership=False)
    summarize("EEP (elastic membership, continuation)", rt, eng, fe)
    rt2, eng2, fe2 = run(fixed_membership=True)
    summarize("fixed membership (full restart, client retries)",
              rt2, eng2, fe2)


if __name__ == "__main__":
    main()
