"""The Fig. 10 experiment at your fingertips: failure scales f1..f16 on a
32-rank instance; prints the recovery phase breakdown and repair-source mix
(watch GPU relocation give way to DRAM reload as replicas run out).

  PYTHONPATH=src python examples/elastic_reintegration.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.recovery import run


def main():
    for row in run(scales=(1, 2, 4, 8, 16)):
        m = row["mix"]
        print(f"f={row['failed']:<3d} total={row['total_s']:.2f}s  "
              f"xfer={row['weight_transfer_s']:.2f}s  "
              f"mix: local={m.get('local_reuse', 0)} "
              f"reloc={m.get('gpu_relocation', 0)} "
              f"dram={m.get('dram_reload', 0)}  "
              f"post-throughput={row['post_recovery_throughput_frac']:.2f}")


if __name__ == "__main__":
    main()
